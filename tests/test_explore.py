"""Design-space exploration subsystem (repro.explore).

The compilation cache these sweeps write is isolated per-test by the
autouse ``_isolated_stripe_cache`` conftest fixture (STRIPE_CACHE_DIR ->
tmpdir), and every sweep here additionally passes an explicit tmpdir
``cache_dir`` — explore runs never touch ``~/.cache/stripe-repro``.
"""
import json

import pytest

from repro.core.hwconfig import get_config
from repro.explore import (
    Axis,
    SearchSpace,
    apply_axis,
    build_report,
    dominating_baseline,
    get_space,
    get_workloads,
    pareto_front,
    run_sweep,
    to_markdown,
    write_report,
)
from repro.explore.runner import PointResult


def _tiny_space() -> SearchSpace:
    """A fast CPU space whose axes provably change predicted latency
    (bandwidth scales t_mem, peak-flops scales t_compute)."""
    return SearchSpace(
        name="tiny-cpu", base="cpu_test",
        axes=(
            Axis("mem.RAM.bandwidth", (50e9, 200e9), default=50e9),
            Axis("peak_flops", (1e11, 8e11), default=1e11),
        ))


# --------------------------------------------------------------------------
# space
# --------------------------------------------------------------------------
def test_space_grid_leads_with_stock_and_respects_budget():
    sp = get_space("tpu-sweep")
    pts = sp.grid(9)
    assert len(pts) == 9
    assert pts[0] == sp.default_point()
    assert sp.point_name(pts[0]) == "tpu_v5e"
    # subsample keeps points unique
    keys = {tuple(p[a.path] for a in sp.axes) for p in pts}
    assert len(keys) == 9


def test_space_grid_budget_one_is_just_the_stock_point():
    sp = get_space("tpu-sweep")
    assert sp.grid(1) == [sp.default_point()]
    assert len(sp.grid(2)) == 2


def test_space_apply_pipeline_variant_and_params():
    sp = get_space("tpu-sweep")
    point = dict(sp.default_point())
    point["pipeline"] = "no-fuse"
    point["autotile.mem_cap_frac"] = 0.9
    hw = sp.apply(point)
    assert all(name != "fuse" for name, _ in hw.passes)
    assert dict(hw.passes)["autotile"]["mem_cap_frac"] == 0.9
    assert "no-fuse" in hw.name and "0.9" in hw.name


def test_space_stock_point_fingerprints_equal_base():
    for name in ("tpu-sweep", "cacheline-sweep"):
        sp = get_space(name)
        assert sp.apply(sp.default_point()).fingerprint() == \
            sp.base_config().fingerprint()


def test_apply_axis_paths_and_errors():
    hw = get_config("tpu_v5e")
    assert apply_axis(hw, "mem.VMEM.size_bytes", 1 << 20).mem("VMEM").size_bytes == 1 << 20
    assert apply_axis(hw, "stencil.mxu.dims", (256, 256, 128)).stencils[0].dims == (256, 256, 128)
    assert apply_axis(hw, "peak_flops", 1.0).peak_flops == 1.0
    with pytest.raises(ValueError):
        apply_axis(hw, "not.a.real.path", 1)
    with pytest.raises(KeyError):
        apply_axis(hw, "pipeline", "no-such-variant")
    with pytest.raises(KeyError):
        get_space("no-such-space")


def test_space_random_is_seeded_and_deduped():
    sp = _tiny_space()
    a = sp.random(4, seed=7)
    b = sp.random(4, seed=7)
    assert a == b
    keys = {tuple(p[ax.path] for ax in sp.axes) for p in a}
    assert len(keys) == len(a) == 4  # tiny space: all points enumerable


# --------------------------------------------------------------------------
# workloads
# --------------------------------------------------------------------------
def test_workload_corpus_builds_valid_programs():
    from repro.core import validate_program

    for w in get_workloads("all"):
        prog = w.build()
        validate_program(prog)
        assert prog.inputs and prog.outputs


def test_get_workloads_specs():
    assert [w.name for w in get_workloads("quick")] == ["mm_bias_gelu", "fig4_conv"]
    assert [w.name for w in get_workloads("attn_scores,moe_ffn")] == \
        ["attn_scores", "moe_ffn"]
    with pytest.raises(KeyError):
        get_workloads("no_such_workload")


# --------------------------------------------------------------------------
# pareto
# --------------------------------------------------------------------------
def _pt(i, lat, vmem, kern, dedup=None, err=""):
    return PointResult(index=i, config_name=f"c{i}", fingerprint=f"f{i}",
                       point={}, latency_s=lat, vmem_peak_bytes=vmem,
                       n_kernels=kern, dedup_of=dedup, error=err)


def test_pareto_front_extracts_non_dominated_set():
    pts = [
        _pt(0, 1.0, 100, 2),   # dominated by 1
        _pt(1, 0.5, 100, 2),   # front
        _pt(2, 0.8, 50, 2),    # front (better vmem)
        _pt(3, 0.5, 100, 1),   # front (dominates 1 on kernels)
        _pt(4, 0.5, 100, 1, dedup=3),  # deduped: excluded
        _pt(5, 9.9, 999, 9, err="boom"),  # errored: excluded
    ]
    assert set(pareto_front(pts)) == {2, 3}
    # point 1 is dominated by 3 (equal latency+vmem, fewer kernels)
    assert 1 not in pareto_front(pts)


# --------------------------------------------------------------------------
# runner: end-to-end sweeps
# --------------------------------------------------------------------------
def test_grid_sweep_scores_dedupes_and_dominates(tmp_path):
    sp = _tiny_space()
    sweep = run_sweep(sp, "quick", budget=4, strategy="grid",
                      cache_dir=str(tmp_path / "cache"))
    assert len(sweep.points) == 4
    assert not any(p.error for p in sweep.points)
    # the stock point dedupes against the baseline compile (-1)
    assert sweep.points[0].dedup_of == -1
    assert sweep.points[0].latency_s == sweep.baseline.latency_s > 0
    # every point carries per-workload scores on the corpus
    for p in sweep.points:
        assert set(p.scores) == {"mm_bias_gelu", "fig4_conv"}
        assert p.vmem_peak_bytes > 0 and p.n_kernels > 0
    # 4x bandwidth + 8x flops strictly dominates stock predicted latency
    dom = dominating_baseline(sweep)
    assert any(dom.values()), dom
    best = min(sweep.unique_points(), key=lambda p: p.latency_s)
    assert best.latency_s < sweep.baseline.latency_s


def test_sweep_dedupes_equal_fingerprints_between_points(tmp_path):
    # two pipeline-irrelevant settings of fuse.prefer under no-fuse
    sp = SearchSpace(
        name="collide", base="tpu_v5e",
        axes=(
            Axis("pipeline", ("no-fuse",), default="no-fuse"),
            Axis("fuse.prefer", ("epilogue", "prologue"), default="epilogue"),
        ))
    sweep = run_sweep(sp, "quick", budget=4, strategy="grid",
                      cache_dir=str(tmp_path / "cache"))
    dedup = [p for p in sweep.points if p.dedup_of is not None and p.dedup_of >= 0]
    assert len(dedup) == 1
    orig = sweep.points[dedup[0].dedup_of]
    assert dedup[0].fingerprint == orig.fingerprint
    assert dedup[0].scores == orig.scores
    # only unique fingerprints were compiled: stats show no re-search
    assert sweep.cache_stats["puts"] > 0


def test_hillclimb_sweep_improves_or_matches_baseline(tmp_path):
    sp = _tiny_space()
    sweep = run_sweep(sp, "quick", budget=5, strategy="hillclimb", seed=1,
                      cache_dir=str(tmp_path / "cache"))
    assert 1 <= len(sweep.points) <= 5
    assert not any(p.error for p in sweep.points)
    best = min(p.latency_s for p in sweep.unique_points())
    assert best <= sweep.baseline.latency_s


def test_sweep_without_disk_cache_still_scores():
    sp = _tiny_space()
    sweep = run_sweep(sp, "quick", budget=2, strategy="grid", cache_dir=None)
    assert not any(p.error for p in sweep.points)
    assert sweep.baseline.latency_s > 0


def test_validation_measures_top_k_on_jnp(tmp_path):
    sp = _tiny_space()
    sweep = run_sweep(sp, "quick", budget=2, strategy="grid",
                      cache_dir=str(tmp_path / "cache"),
                      measure_top_k=1, measure_backend="jnp")
    v = sweep.validation
    assert v is not None and v["backend"] == "jnp"
    # baseline + top-1, each measured on the real backend
    assert len(v["entries"]) == 2
    for e in v["entries"]:
        assert e["error"] == ""
        assert e["measured_total_us"] > 0
        assert set(e["measured_us"]) == {"mm_bias_gelu", "fig4_conv"}
    assert sorted(v["predicted_rank"]) == sorted(v["measured_rank"])
    # the estimator and its round/call counts are part of the result
    assert v["estimator"] == "min-of-interleaved-rounds"
    assert v["rounds"] >= 1 and v["calls"] >= 1


# --------------------------------------------------------------------------
# report + CLI
# --------------------------------------------------------------------------
def test_report_json_and_markdown(tmp_path):
    sp = _tiny_space()
    sweep = run_sweep(sp, "quick", budget=3, strategy="grid",
                      cache_dir=str(tmp_path / "cache"))
    doc = build_report(sweep)
    assert doc["n_points"] == 3 and doc["n_errors"] == 0
    assert doc["n_unique"] + doc["n_deduped"] == 3
    assert doc["baseline"]["latency_s"] > 0
    assert isinstance(doc["pareto_front"], list) and doc["pareto_front"]
    md = to_markdown(sweep)
    assert "baseline" in md and "Pareto" in md
    jpath, mpath = write_report(sweep, str(tmp_path / "out"))
    loaded = json.loads(jpath.read_text())
    assert loaded["space"] == "tiny-cpu"
    assert mpath.read_text() == md


def test_cli_main_end_to_end(tmp_path):
    from repro.explore.__main__ import main

    out = tmp_path / "cli_out"
    rc = main(["--space", "tpu-sweep", "--workloads", "quick", "--budget", "4",
               "--top-k", "0", "--out", str(out)])
    assert rc == 0
    doc = json.loads((out / "explore_report.json").read_text())
    assert doc["n_points"] == 4
    assert (out / "explore_report.md").exists()
    # the sweep cache landed under --out, not the user's home cache
    assert (out / "cache").is_dir() and any((out / "cache").iterdir())


def test_bench_hillclimb_rows_still_emitted(capsys):
    from repro.explore.hillclimb import roofline_hillclimb

    rows = []
    roofline_hillclimb(emit=lambda n, us, d: rows.append((n, us, d)))
    names = [r[0] for r in rows]
    assert "stripe_hillclimb/autotile" in names
    assert "stripe_hillclimb/pipeline_fuses_ffn" in names
    assert rows[-1][2] == 1  # the pipeline really fuses the ffn
