"""Liveness-based VMEM memory planner (core/memplan.py) + the pipelined
wavefront cost model it feeds (core/cost.py).

The load-bearing property: the interval-graph best-fit allocator never
hands two views with overlapping live intervals overlapping address
ranges (hypothesis), while reusing dead views' space.  Plus: slot
classification (streamed / resident / accumulator), the planner-exact
autotile feasibility unlock vs the legacy ``*2`` rule, fusion-pressure
differences, pipelined latency gating by ``pipeline_depth``, wavefront-
overlap scoring, and the schedule-pass integration (arena tags, slot
addresses)."""
import copy
import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TileProgram, single_op_program, stripe_jit
from repro.core.cost import (
    evaluate_tiling,
    pipelined_latency,
    score_pass_trace,
)
from repro.core.hwconfig import get_config
from repro.core.memplan import (
    ARENA_ALIGN,
    ViewSpec,
    allocate,
    bump_bytes,
    plan_block,
    plan_program,
)
from repro.core.passes import get_pass


# --------------------------------------------------------------------------
# allocator
# --------------------------------------------------------------------------
def _overlap(a, b):
    return a.view.start <= b.view.end and b.view.start <= a.view.end


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**9))
def test_property_allocations_never_overlap_live_intervals(seed):
    """The acceptance property: concurrently-live views never share
    bytes; every slot is aligned; the packed peak never exceeds the
    legacy bump model."""
    rng = random.Random(seed)
    views = []
    for i in range(rng.randint(1, 14)):
        start = rng.randint(0, 6)
        views.append(ViewSpec(
            name=f"v{i}", nbytes=rng.randint(1, 300 * 1024),
            slots=rng.randint(1, 2), start=start, end=rng.randint(start, 8)))
    allocs, peak = allocate(views)
    assert len(allocs) == len(views)
    for a in allocs:
        assert a.addr % ARENA_ALIGN == 0
        assert a.addr + a.nbytes <= peak
    for i, a in enumerate(allocs):
        for b in allocs[i + 1:]:
            if _overlap(a, b):
                assert a.addr + a.nbytes <= b.addr or b.addr + b.nbytes <= a.addr, \
                    f"live-overlapping views share bytes: {a} vs {b}"
    assert peak <= bump_bytes(views)


def test_allocator_reuses_dead_views_space():
    views = [
        ViewSpec(name="a", nbytes=1024, start=0, end=0),
        ViewSpec(name="b", nbytes=1024, start=0, end=2),
        ViewSpec(name="c", nbytes=1024, start=1, end=2),  # reuses a's slot
    ]
    allocs, peak = allocate(views)
    by_name = {a.view.name: a for a in allocs}
    assert by_name["c"].addr == by_name["a"].addr
    assert peak == 2 * 1024


def test_allocator_best_fit_prefers_smallest_gap():
    # layout at interval 0 (by size, then name):
    #   a_rel@0 (4096, dies) | b_keep@4096 | c_rel@4608 (512, dies) | d_keep@5120
    views = [
        ViewSpec(name="a_rel", nbytes=4096, start=0, end=0),
        ViewSpec(name="b_keep", nbytes=512, start=0, end=3),
        ViewSpec(name="c_rel", nbytes=512, start=0, end=0),
        ViewSpec(name="d_keep", nbytes=512, start=0, end=3),
        ViewSpec(name="fill", nbytes=512, start=1, end=1),
    ]
    allocs, _ = allocate(views)
    by_name = {a.view.name: a for a in allocs}
    assert by_name["b_keep"].addr == 4096 and by_name["c_rel"].addr == 4608
    # 'fill' lands in the released 512B gap between the keepers, not the
    # released 4096B region below them
    assert by_name["fill"].addr == by_name["c_rel"].addr


# --------------------------------------------------------------------------
# plan_block classification
# --------------------------------------------------------------------------
def _tiled_matmul_block(m=256, k=256, n=256, tiles=None):
    from repro.core.tiling import split_block

    prog = single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((m, k), "float32"), "B": ((k, n), "float32"),
         "O": ((m, n), "float32")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    return split_block(blk, tiles or {"i": 128, "c": 128})


def test_plan_block_grid_slots_and_scratch():
    g = _tiled_matmul_block()  # grid over i (output) and c (reduction)
    plan = plan_block(g, depth=2)
    assert plan.grid
    assert set(plan.red_vars) == {"c"} and set(plan.parallel_vars) == {"i"}
    kinds = {a.view.name: (a.view.kind, a.view.slots) for a in plan.allocs}
    assert kinds["A"] == ("stream", 2)           # addressed by i and c
    assert kinds["B"] == ("stream", 2)           # addressed by c
    assert kinds["O_out"] == ("acc", 1)          # revisited across c
    assert kinds["O_out.acc"] == ("scratch", 1)  # f32 partial sums
    assert plan.acc_bytes == 128 * 256 * 4
    # streamed double-buffering beats blanket double-buffering strictly
    assert 0 < plan.peak_bytes < plan.bump_bytes


def test_plan_block_resident_weight_single_slot():
    g = _tiled_matmul_block(tiles={"i": 128})  # B is grid-invariant
    plan = plan_block(g, depth=2)
    kinds = {a.view.name: (a.view.kind, a.view.slots) for a in plan.allocs}
    assert kinds["B"] == ("resident", 1)
    assert kinds["A"] == ("stream", 2)


def test_plan_flat_fused_block_liveness_reuse():
    """A fused flat block's operand views die before the epilogue's
    views go live — the planner's arena is strictly below the bump
    model on the same views."""
    tp = TileProgram("mlp")
    tp.input("A", (64, 64))
    tp.input("B", (64, 64))
    tp.input("b", (64,))
    tp.temp("T", (64, 64))
    tp.output("O", (64, 64))
    tp.op("T[i, j] += A[i, c] * B[c, j]", name="mm")
    tp.op("O[i, j] = relu(T[i, j] + b[j])", name="bias")
    prog = tp.build()
    fused = get_pass("fuse")(prog, get_config("tpu_v5e"), {})
    blk = [s for s in fused.entry.stmts if hasattr(s, "refs")][0]
    plan = plan_block(blk, depth=2)
    assert not plan.grid
    assert 0 < plan.peak_bytes < plan.bump_bytes


def test_plan_program_packs_sequential_levels():
    blocks = []
    for name in ("p", "q"):
        tp = TileProgram(name)
        tp.input("A", (64, 64))
        tp.output("O", (64, 64))
        tp.op("O[i, j] = relu(A[i, j])", name=name)
        blocks.append(tp.build().entry.stmts[0])
    seq = plan_program([(blocks[0], 0), (blocks[1], 1)])
    par = plan_program([(blocks[0], 0), (blocks[1], 0)])
    per = seq.block_plans[blocks[0].name].peak_bytes
    assert seq.peak_bytes == per            # level 1 reuses level 0's arena
    assert par.peak_bytes == 2 * per        # same level: arenas coexist
    assert seq.bump_bytes == par.bump_bytes > seq.peak_bytes


# --------------------------------------------------------------------------
# pipelined latency + wavefront scoring
# --------------------------------------------------------------------------
def test_pipelined_latency_gating():
    # no double buffering (or a single tile): terms serialize
    assert pipelined_latency(8.0, 4.0, 10, depth=1) == 12.0
    assert pipelined_latency(8.0, 4.0, 1, depth=2) == 12.0
    # steady state hides the smaller term: prologue + (n-1)*max + drain
    got = pipelined_latency(8.0, 4.0, 10, depth=2)
    assert got == pytest.approx(0.8 + 9 * 0.8 + 0.4)
    assert max(8.0, 4.0) < got < 12.0


def test_score_pass_trace_overlaps_wavefront_levels():
    rec_a = {"block": "a", "t_mem": 3.0, "t_compute": 1.0, "latency_s": 3.5}
    rec_b = {"block": "b", "t_mem": 2.0, "t_compute": 1.0, "latency_s": 2.5}
    autotile = ("autotile", {}, [rec_a, rec_b])
    parallel = ("schedule", {}, [
        {"block": "a.grid", "level": 0, "arena_bytes": 100, "arena_bump_bytes": 300},
        {"block": "b", "level": 0, "arena_bytes": 200, "arena_bump_bytes": 400},
    ])
    serial = ("schedule", {}, [
        {"block": "a.grid", "level": 0, "arena_bytes": 100, "arena_bump_bytes": 300},
        {"block": "b", "level": 1, "arena_bytes": 200, "arena_bump_bytes": 400},
    ])
    par = score_pass_trace([autotile, parallel])
    ser = score_pass_trace([autotile, serial])
    # one level: mem/compute streams overlap -> max(sum mem, sum comp, lat)
    assert par.latency_s == pytest.approx(5.0)
    assert par.n_levels == 1
    # two levels: blocks serialize at their pipelined latencies
    assert ser.latency_s == pytest.approx(3.5 + 2.5)
    assert ser.n_levels == 2
    for sc in (par, ser):
        assert sc.latency_serial_s == pytest.approx(6.0)
        assert sc.vmem_bump_peak_bytes == 400
    # a trace with no schedule levels degrades to the serial sum
    bare = score_pass_trace([autotile])
    assert bare.latency_s == pytest.approx(6.0)


# --------------------------------------------------------------------------
# autotile feasibility: the *2 rule vs the planner's exact footprint
# --------------------------------------------------------------------------
def test_evaluate_tiling_planner_unlocks_larger_tiles():
    """A tile whose blanket-double-buffered footprint busts the cap is
    feasible under the planner (resident weight one slot, revisited
    output one slot + scratch)."""
    prog = single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((1024, 512), "float32"), "B": ((512, 512), "float32"),
         "O": ((1024, 512), "float32")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    # cap = 0.45 * 12 MiB = 5.66 MB: between the planner footprint
    # (2A + B + 2O = 5.24 MB) and the legacy rule (2(A+B+O) = 6.29 MB)
    hw = get_config("tpu_v5e").with_mem("VMEM", size_bytes=12 * 2**20)
    tiles = {"i": 512}  # B fully resident, O streamed, A streamed
    base = {"cost": "roofline", "mem_cap_frac": 0.45}
    new = evaluate_tiling(blk, tiles, hw, base)
    old = evaluate_tiling(blk, tiles, hw, dict(base, memplan=False))
    assert new.feasible and not old.feasible
    assert "2x tile bytes" in old.why
    assert new.plan_bytes < 2 * new.mem_bytes
    # the pipelined per-block latency rides along in both models
    assert new.latency_s > 0


def test_evaluate_tiling_planner_footprint_counts_scratch():
    """With every view streamed and a gridded reduction, the planner is
    *not* cheaper than 2x — the f32 scratch is priced honestly."""
    prog = single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((256, 256), "bfloat16"), "B": ((256, 256), "bfloat16"),
         "O": ((256, 256), "bfloat16")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    hw = get_config("tpu_v5e")
    c = evaluate_tiling(blk, {"i": 128, "j": 128, "c": 128}, hw,
                        {"cost": "roofline", "mem_cap_frac": 0.45})
    # 2xA + 2xB + O + f32 scratch (scratch is 2x a bf16 out tile)
    assert c.plan_bytes == 2 * (128 * 128 * 2) * 2 + 128 * 128 * 2 + 128 * 128 * 4


# --------------------------------------------------------------------------
# schedule-pass integration
# --------------------------------------------------------------------------
def _compile(prog, hw):
    from repro.core.passes import PassManager

    pm = PassManager(hw)
    out = pm.run(copy.deepcopy(prog))
    return out, pm.trace


def test_schedule_pass_tags_planner_and_bump_arenas():
    tp = TileProgram("two")
    tp.input("A", (256, 256))
    tp.input("B", (256, 256))
    tp.temp("T", (256, 256))
    tp.output("O", (256, 256))
    tp.op("T[i, j] += A[i, c] * B[c, j]", name="mm")
    tp.op("O[i, j] = relu(T[i, j])", name="act")
    opt, trace = _compile(tp.build(), get_config("tpu_v5e"))
    sched = [r for e in trace if e[0] == "schedule" for r in e[2]]
    blocks = [r for r in sched if "level" in r]
    assert blocks and all(r["arena_bytes"] <= r["arena_bump_bytes"] for r in blocks)
    prog_plan = [r for r in sched if "program_plan" in r]
    assert prog_plan and prog_plan[0]["program_plan"]["peak_bytes"] > 0
    tags = {t for s in opt.entry.stmts if hasattr(s, "tags") for t in s.tags}
    assert any(t.startswith("arena:") for t in tags)
    assert any(t.startswith("arena_bump:") for t in tags)


def test_schedule_pass_assigns_non_overlapping_slot_addresses():
    prog = single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((256, 128), "float32"), "B": ((128, 256), "float32"),
         "O": ((256, 256), "float32")},
        out="O",
    )
    opt, _ = _compile(prog, get_config("tpu_v5e"))
    top = [s for s in opt.entry.stmts if hasattr(s, "walk")][0]
    plan = plan_block(top, depth=get_config("tpu_v5e").pipeline_depth)
    spans = {a.view.name: (a.addr, a.addr + a.nbytes) for a in plan.allocs}
    names = sorted(spans)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            sa, sb = spans[a], spans[b]
            assert sa[1] <= sb[0] or sb[1] <= sa[0]
    # the planned bases landed on the inner VMEM refinements
    addrs = [r.location.addr for g in top.walk() if g is not top
             for r in g.refs
             if r.location and r.location.unit == "VMEM" and r.location.addr is not None]
    assert addrs and all(a % ARENA_ALIGN == 0 for a in addrs)


def test_legacy_memplan_param_restores_bump_behavior():
    hw = get_config("tpu_v5e").with_params(**{"schedule.memplan": False})
    prog = single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((256, 128), "float32"), "B": ((128, 256), "float32"),
         "O": ((256, 256), "float32")},
        out="O",
    )
    opt, trace = _compile(prog, hw)
    sched = [r for e in trace if e[0] == "schedule" for r in e[2]]
    assert all("arena_bump_bytes" not in r for r in sched)
    tags = {t for s in opt.entry.stmts if hasattr(s, "walk")
            for b in s.walk() for t in b.tags}
    assert not any(t.startswith("arena_bump:") for t in tags)


# --------------------------------------------------------------------------
# end-to-end capacity unlock (the memplan bench, reduced)
# --------------------------------------------------------------------------
def _chain_prog(m=256, n=4096, n2=32):
    tp = TileProgram("memplan_chain")
    tp.input("X", (m, n))
    tp.input("W2", (n, n2))
    tp.temp("Y1", (m, n))
    tp.temp("Y2", (m, n))
    tp.temp("X2", (m, n))
    tp.output("O", (m, n2))
    tp.op("Y1[i, j] = relu(X[i, j])", name="pre1")
    tp.op("Y2[i, j] = square(Y1[i, j])", name="pre2")
    tp.op("X2[i, j] = abs(Y2[i, j])", name="pre3")
    tp.op("O[i, j2] += X2[i, j] * W2[j, j2]", name="mm")
    return tp.build()


def test_planner_unlocks_fusion_and_larger_tiles_end_to_end():
    """On a VMEM-tight config whose cap sits between the planner's exact
    pressure and the legacy 2x pressure: the planner fuses the whole
    elementwise chain into the matmul kernel (1 group vs 4) and the
    legacy model cannot afford the planner's tile."""
    hw = (get_config("tpu_v5e").with_mem("VMEM", size_bytes=16 * 2**20)
          .with_params(**{"autotile.mem_cap_frac": 0.29,
                          "fuse.mem_cap_frac": 0.29}))
    legacy = hw.with_params(**{"fuse.memplan": False, "autotile.memplan": False,
                               "schedule.memplan": False})
    cp = stripe_jit(_chain_prog(), hw, backend="jnp", use_disk=False)
    cl = stripe_jit(_chain_prog(), legacy, backend="jnp", use_disk=False)
    assert cp.record.groups == [["pre1", "pre2", "pre3", "mm"]]
    assert cl.record.n_kernels == 4
    rejected = [d for d in cl.record.fusion_decisions() if not d["accepted"]]
    assert rejected and "arena" in rejected[0]["reason"]

    def mm_rec(rec):
        return next(r for e in rec.pass_trace if e[0] == "autotile"
                    for r in e[2] if r["block"] == "mm")

    mm_p, mm_l = mm_rec(cp.record), mm_rec(cl.record)
    cap = int(16 * 2**20 * 0.29)
    assert mm_p["mem_bytes"] > mm_l["mem_bytes"]          # larger tile
    assert 2 * mm_p["mem_bytes"] > cap >= mm_p["plan_bytes"]  # old-rule-infeasible
    # whole-workload predicted latency: the fused compile wins
    lat_p = score_pass_trace(cp.record.pass_trace).latency_s
    lat_l = score_pass_trace(cl.record.pass_trace).latency_s
    assert lat_p < lat_l
    # both compiles stay semantically correct
    rng = np.random.RandomState(0)
    ins = {"X": rng.randn(256, 4096).astype(np.float32),
           "W2": rng.randn(4096, 32).astype(np.float32)}
    want = np.abs(np.square(np.maximum(ins["X"], 0.0))) @ ins["W2"]
    np.testing.assert_allclose(np.asarray(cp(ins)["O"]), want, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cl(ins)["O"]), want, rtol=1e-4, atol=1e-3)


def test_pipeline_depth_in_fingerprint_and_sweepable():
    import dataclasses

    from repro.explore import apply_axis

    hw = get_config("tpu_v5e")
    assert hw.pipeline_depth == 2
    deeper = apply_axis(hw, "pipeline_depth", 3)
    assert deeper.pipeline_depth == 3
    assert deeper.fingerprint() != hw.fingerprint()
    assert dataclasses.replace(hw, pipeline_depth=2).fingerprint() == hw.fingerprint()
