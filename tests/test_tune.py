"""Measured-feedback autotuning (repro.tune): the persistent tuning DB,
driver replay of measured winners, online cost-model calibration, and
the residual-log rotation that feeds it.

The DB under test always lives in a per-test tmpdir; the autouse
``_isolated_stripe_cache`` conftest fixture keeps the default cache dir
out of ``~/.cache/stripe-repro`` for the code paths that fall back to it.
"""
import json
import multiprocessing
import threading

import jax
import numpy as np
import pytest

from repro import api, configs
from repro.core.cache import CompilationCache
from repro.core.hwconfig import get_config
from repro.models.build import build_model
from repro.obs.profile import (append_residuals, read_residuals,
                               summarize_residuals)
from repro.reliability import faults
from repro.tune import (Calibration, TuningDB, clear_calibrations,
                        entry_key, fit_calibration, load_calibrations,
                        measure_interleaved, save_calibrations,
                        set_calibration)


def _mm():
    return api.single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((32, 16), "float32"), "B": ((16, 24), "float32"),
         "O": ((32, 24), "float32")},
        out="O")


def _mm_arrays(seed=0):
    rng = np.random.RandomState(seed)
    return {"A": rng.randn(32, 16).astype(np.float32),
            "B": rng.randn(16, 24).astype(np.float32)}


# --------------------------------------------------------------------------
# TuningDB basics
# --------------------------------------------------------------------------
def test_db_record_lookup_roundtrip(tmp_path):
    db = TuningDB(dir=tmp_path)
    tilings = {"mm#abc": {"i": 8, "j": 8}}
    cid = db.record("ir1", "hw1", "pallas", True, tilings=tilings,
                    measured_s=2e-3, predicted_s=1e-3, rounds=4, calls=2,
                    source="test", workload="mm")
    assert len(db) == 1
    e = db.lookup("ir1", "hw1", "pallas", True)
    assert e is not None and e.candidate_id == cid
    assert e.tilings == tilings and e.measured_s == 2e-3
    assert e.source == "test" and e.workload == "mm" and e.rounds == 4
    # identity is the full (ir, hw, backend, interpret) tuple
    assert db.lookup("ir1", "hw1", "pallas", False) is None
    assert db.lookup("ir1", "hw2", "pallas", True) is None
    assert db.lookup("other", "hw1", "pallas", True) is None
    # a fresh handle over the same dir sees the same entry
    e2 = TuningDB(dir=tmp_path).lookup("ir1", "hw1", "pallas", True)
    assert e2 is not None and e2.candidate_id == cid


def test_db_best_candidate_min_wins(tmp_path):
    db = TuningDB(dir=tmp_path)
    slow = {"mm#abc": {"i": 4}}
    fast = {"mm#abc": {"i": 16}}
    db.record("ir", "hw", "jnp", True, tilings=slow, measured_s=5e-3)
    db.record("ir", "hw", "jnp", True, tilings=fast, measured_s=1e-3)
    assert db.lookup("ir", "hw", "jnp", True).tilings == fast
    # re-measuring an existing candidate keeps the minimum
    db.record("ir", "hw", "jnp", True, tilings=fast, measured_s=9e-3)
    e = db.lookup("ir", "hw", "jnp", True)
    assert e.tilings == fast and e.measured_s == 1e-3
    # a new measurement below the floor takes over
    db.record("ir", "hw", "jnp", True, tilings=slow, measured_s=5e-4)
    assert db.lookup("ir", "hw", "jnp", True).tilings == slow


def test_db_freshness_bound(tmp_path):
    db = TuningDB(dir=tmp_path)
    db.record("ir", "hw", "jnp", True, tilings={"b#x": {"i": 4}},
              measured_s=1e-3)
    assert db.lookup("ir", "hw", "jnp", True, max_age_s=3600) is not None
    # everything is staler than a negative bound
    assert db.lookup("ir", "hw", "jnp", True, max_age_s=-1.0) is None
    # the DB-level default applies when the call doesn't override
    stale_db = TuningDB(dir=tmp_path, max_age_s=-1.0)
    assert stale_db.lookup("ir", "hw", "jnp", True) is None
    assert stale_db.lookup("ir", "hw", "jnp", True, max_age_s=3600) is not None


# --------------------------------------------------------------------------
# TuningDB concurrency + durability
# --------------------------------------------------------------------------
def test_db_thread_concurrency_no_lost_entries(tmp_path):
    db = TuningDB(dir=tmp_path)
    n = 8

    def worker(i):
        db.record("ir", "hw", "jnp", True,
                  tilings={"b#x": {"i": i + 1}}, measured_s=float(i + 1),
                  source=f"thread{i}")

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    entry = db.entries()[entry_key("ir", "hw", "jnp", True)]
    assert len(entry["candidates"]) == n, "concurrent records must not lose"
    assert db.lookup("ir", "hw", "jnp", True).measured_s == 1.0


def _record_in_subprocess(args):
    # module-level so the fork-spawned pool can pickle it
    d, i = args
    db = TuningDB(dir=d)
    db.record("ir", "hw", "jnp", True,
              tilings={"b#x": {"i": i + 1}}, measured_s=float(i + 1),
              source=f"proc{i}")
    return i


def test_db_process_concurrency_no_lost_entries(tmp_path):
    n = 8
    ctx = multiprocessing.get_context("fork")
    with ctx.Pool(4) as pool:
        done = pool.map(_record_in_subprocess, [(str(tmp_path), i)
                                                for i in range(n)])
    assert sorted(done) == list(range(n))
    entry = TuningDB(dir=tmp_path).entries()[entry_key("ir", "hw", "jnp", True)]
    assert len(entry["candidates"]) == n, "cross-process records must not lose"


def test_db_torn_write_recovered(tmp_path):
    db = TuningDB(dir=tmp_path)
    with faults.inject(faults.fail_nth("cache.disk_write_torn", 1)):
        db.record("ir", "hw", "jnp", True, tilings={"b#x": {"i": 4}},
                  measured_s=1e-3)
    assert db.write_errors == 1
    # the torn document landed at the final path
    with pytest.raises(json.JSONDecodeError):
        json.loads((tmp_path / "tuning_db.json").read_text())
    # a fresh handle recovers (moves the wreck aside) instead of raising
    db2 = TuningDB(dir=tmp_path)
    assert len(db2) == 0 and db2.recovered == 1
    assert (tmp_path / "tuning_db.corrupt").exists()
    # and the DB is immediately writable again
    db2.record("ir", "hw", "jnp", True, tilings={"b#x": {"i": 4}},
               measured_s=1e-3)
    assert db2.lookup("ir", "hw", "jnp", True).measured_s == 1e-3


def test_corrupt_db_never_fails_the_compile(tmp_path):
    (tmp_path / "tuning_db.json").write_text("{definitely not json")
    db = TuningDB(dir=tmp_path)
    cache = CompilationCache(disk_dir=tmp_path)
    c = api.stripe_jit(_mm(), get_config("cpu_test"), cache=cache, tune=db)
    assert c.record.decision_source == "analytic"
    assert db.recovered >= 1
    out = c(_mm_arrays())["O"]
    assert out.shape == (32, 24)


# --------------------------------------------------------------------------
# driver integration: tuned replay
# --------------------------------------------------------------------------
def test_stripe_jit_tuned_replay(tmp_path):
    hw = get_config("cpu_test")
    cache = CompilationCache(disk_dir=tmp_path)
    db = TuningDB(dir=tmp_path)
    c1 = api.stripe_jit(_mm(), hw, cache=cache, tune=db)
    assert c1.record.decision_source == "analytic" and not c1.record.tuned
    assert cache.stats.tuned_misses == 1 and cache.stats.tuned_hits == 0
    # record a measured winner with a deliberately different tiling
    alt = {k: {v: max(1, t // 2) for v, t in tiles.items()}
           for k, tiles in c1.record.tilings.items()}
    assert alt != c1.record.tilings
    cid = db.record(c1.record.ir_fingerprint, c1.record.hw_fingerprint,
                    "jnp", True, tilings=alt, measured_s=1e-4,
                    predicted_s=2e-4, rounds=4, source="test")
    # a fresh cache instance over the same disk dir = a new process
    cache2 = CompilationCache(disk_dir=tmp_path)
    c2 = api.stripe_jit(_mm(), hw, cache=cache2, tune=db)
    assert c2.record.decision_source == "tuned"
    assert c2.record.tuned["candidate_id"] == cid
    assert c2.record.tuned["source"] == "test"
    assert c2.record.tilings == alt, "replay must compile the measured tiling"
    assert cache2.stats.tuned_hits == 1
    # different tiling, same math
    arrays = _mm_arrays()
    np.testing.assert_allclose(np.asarray(c1(arrays)["O"]),
                               np.asarray(c2(arrays)["O"]),
                               rtol=1e-5, atol=1e-5)
    # second compile in the same process: memory hit under the tuned key
    c3 = api.stripe_jit(_mm(), hw, cache=cache2, tune=db)
    assert c3.record.cache_hit and c3.record.decision_source == "tuned"
    assert cache2.stats.tuned_hits == 2


def test_compile_with_tilings_fixed_replay():
    hw = get_config("cpu_test")
    c1 = api.stripe_jit(_mm(), hw, use_disk=False)
    alt = {k: {v: max(1, t // 2) for v, t in tiles.items()}
           for k, tiles in c1.record.tilings.items()}
    c2 = api.compile_with_tilings(_mm(), hw, alt, backend="jnp")
    assert c2.record.decision_source == "replay"
    assert c2.record.tilings == alt
    arrays = _mm_arrays()
    np.testing.assert_allclose(np.asarray(c1(arrays)["O"]),
                               np.asarray(c2(arrays)["O"]),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# measure mode (explore integration)
# --------------------------------------------------------------------------
def test_measure_interleaved_min_of_rounds():
    calls = {"a": 0, "b": 0}

    def mk(name):
        def thunk():
            calls[name] += 1
        return thunk

    ms = measure_interleaved({"a": mk("a"), "b": mk("b")}, rounds=3, calls=2,
                             warmup=1)
    assert set(ms) == {"a", "b"}
    for m in ms.values():
        assert m.rounds == 3 and m.calls == 2
        assert m.min_s == min(m.all_rounds_s) > 0
    # warmup + rounds * calls per thunk
    assert calls == {"a": 7, "b": 7}


def test_measure_candidates_populates_db(tmp_path):
    from repro.explore import Axis, SearchSpace

    sp = SearchSpace(
        name="tiny-cpu", base="cpu_test",
        axes=(Axis("mem.RAM.bandwidth", (50e9, 200e9), default=50e9),))
    db = TuningDB(dir=tmp_path)
    sweep = api.run_sweep(sp, "fig4_conv", budget=2, strategy="grid",
                          cache_dir=str(tmp_path / "cache"), measure=3,
                          tune_db=db)
    ms = sweep.measurement
    assert ms is not None and ms["backend"] == "pallas" and ms["interpret"]
    wl = ms["workloads"]["fig4_conv"]
    assert not wl.get("error")
    assert wl["n_candidates"] >= 2
    assert wl["best_s"] <= wl["analytic_s"], \
        "the analytic tiling is candidate 0, so the min can't lose to it"
    assert len(db) == 1
    e = next(iter(db.entries().values()))
    assert e["backend"] == "pallas" and e["workload"] == "fig4_conv"
    assert len(e["candidates"]) == wl["n_candidates"]
    assert e["best"] == wl["best_candidate"]
    # the recorded winner replays through the tuned compile path
    hw = sp.base_config()
    c = api.stripe_jit(api.get_workloads("fig4_conv")[0].build(), hw,
                       backend="pallas", interpret=True,
                       cache=CompilationCache(disk_dir=tmp_path / "cache"),
                       tune=db)
    assert c.record.decision_source == "tuned"
    assert c.record.tuned["candidate_id"] == wl["best_candidate"]


# --------------------------------------------------------------------------
# calibration
# --------------------------------------------------------------------------
def test_fit_calibration_irls_recovers_scales_despite_outliers():
    rng = np.random.RandomState(0)
    rows = []
    for _ in range(40):
        tm = float(rng.uniform(1e-5, 1e-3))
        tc = float(rng.uniform(1e-5, 1e-3))
        rows.append({"t_mem_raw": tm, "t_compute_raw": tc,
                     "predicted_s": tm + tc,
                     "measured_s": 3.0 * tm + 5.0 * tc + 2e-6})
    for _ in range(5):  # gross outlier dispatches (GC pause, etc.)
        tm = float(rng.uniform(1e-5, 1e-3))
        tc = float(rng.uniform(1e-5, 1e-3))
        rows.append({"t_mem_raw": tm, "t_compute_raw": tc,
                     "predicted_s": tm + tc, "measured_s": 0.5})
    cal = fit_calibration(rows, "hwfp", "jnp")
    assert cal is not None and cal.method == "irls"
    assert cal.hw_fingerprint == "hwfp" and cal.backend == "jnp"
    assert cal.scale_mem == pytest.approx(3.0, rel=0.05)
    assert cal.scale_compute == pytest.approx(5.0, rel=0.05)
    assert 0.0 <= cal.overhead_s < 1e-4


def test_fit_calibration_gmean_fallback_without_terms():
    rows = [{"predicted_s": 1e-4, "measured_s": 4e-4} for _ in range(10)]
    cal = fit_calibration(rows, "hwfp", "jnp")
    assert cal is not None and cal.method == "gmean"
    assert cal.scale_mem == pytest.approx(4.0, rel=1e-6)
    assert cal.scale_compute == pytest.approx(4.0, rel=1e-6)
    assert fit_calibration([], "hwfp") is None


def test_calibration_applied_by_evaluate_tiling():
    hw = get_config("cpu_test")
    prog = _mm()
    blk = prog.entry.stmts[0]
    params = dict(dict(hw.passes)["autotile"])
    tiles = {"i": 8, "j": 8}
    base = api.evaluate_tiling(blk, tiles, hw, params)
    clear_calibrations()
    try:
        set_calibration(Calibration(hw_fingerprint=hw.fingerprint(),
                                    scale_mem=10.0, scale_compute=10.0,
                                    method="test"))
        cal = api.evaluate_tiling(blk, tiles, hw, params)
    finally:
        clear_calibrations()
    # .cost is the paper's cache-line metric; calibration scales the
    # roofline terms and the latency the sweeps rank on
    assert cal.calibrated and not base.calibrated
    assert cal.t_mem == pytest.approx(10 * base.t_mem)
    assert cal.t_compute == pytest.approx(10 * base.t_compute)
    assert cal.latency_s == pytest.approx(10 * base.latency_s)
    assert cal.t_mem_raw == base.t_mem_raw, "raw terms stay uncalibrated"


def test_calibration_rekeys_the_compile_cache(tmp_path):
    hw = get_config("cpu_test")
    cache = CompilationCache(disk_dir=tmp_path)
    c1 = api.stripe_jit(_mm(), hw, cache=cache)
    clear_calibrations()
    try:
        set_calibration(Calibration(hw_fingerprint=hw.fingerprint(),
                                    scale_mem=2.0, scale_compute=2.0,
                                    method="test"))
        c2 = api.stripe_jit(_mm(), hw, cache=cache)
    finally:
        clear_calibrations()
    assert c2.record.key != c1.record.key
    assert not c2.record.cache_hit, \
        "calibrated compiles must never collide with uncalibrated ones"
    c3 = api.stripe_jit(_mm(), hw, cache=cache)
    assert c3.record.cache_hit and c3.record.key == c1.record.key


def test_calibration_save_load_roundtrip(tmp_path):
    cal = Calibration(hw_fingerprint="hwfp", scale_mem=2.5,
                      scale_compute=0.5, overhead_s=1e-6, n_pairs=12,
                      method="irls", backend="jnp")
    assert save_calibrations(tmp_path, cals=[cal]) is not None
    clear_calibrations()
    try:
        loaded = load_calibrations(tmp_path)
        assert len(loaded) == 1
        assert loaded[0].fingerprint() == cal.fingerprint()
        from repro.tune import get_calibration
        assert get_calibration("hwfp").scale_mem == 2.5
    finally:
        clear_calibrations()
    assert load_calibrations(tmp_path / "missing") == []


# --------------------------------------------------------------------------
# residual-log rotation (satellite: bounded growth)
# --------------------------------------------------------------------------
def test_residual_log_rotation_folds_into_db(tmp_path):
    path = tmp_path / "residuals.jsonl"
    rows = [{"backend": "jnp", "hw_fingerprint": "h", "interpret": True,
             "predicted_s": 1e-4, "measured_s": 2e-4} for _ in range(10)]
    append_residuals(rows, path=path, cap=6)
    live = read_residuals(path)
    assert len(live) == 3, "rotation keeps the newest cap//2 rows"
    db = TuningDB(dir=tmp_path)
    folded = db.residual_summaries()
    assert sum(s["rows"] for s in folded) == 7
    summary = summarize_residuals(live, folded=folded)
    assert summary["rows"] == 10
    assert summary["live_rows"] == 3 and summary["folded_rows"] == 7
    assert summary["pairs_with_prediction"] == 10
    # the merged gmean covers the full history, not just the log tail
    assert summary["measured_over_predicted_gmean"] == pytest.approx(2.0)
    assert summary["by_backend"]["jnp"] == 10
    # a second burst keeps folding additively
    append_residuals(rows, path=path, cap=6)
    assert sum(s["rows"] for s in TuningDB(dir=tmp_path).residual_summaries()) \
        == 17


def test_residual_cap_disabled_keeps_everything(tmp_path):
    path = tmp_path / "residuals.jsonl"
    rows = [{"backend": "jnp", "predicted_s": 1e-4, "measured_s": 2e-4}
            for _ in range(30)]
    append_residuals(rows, path=path, cap=0)
    assert len(read_residuals(path)) == 30
    assert not (tmp_path / "tuning_db.json").exists()


# --------------------------------------------------------------------------
# serving-engine opt-in (EngineConfig.tune)
# --------------------------------------------------------------------------
def _tiny_engine_model():
    cfg = configs.get("llama3-8b").scaled(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab=64, head_dim=16, vocab_pad_multiple=16)
    return cfg, build_model(cfg)


def test_engine_tune_consults_and_replays(tmp_path):
    cfg, model = _tiny_engine_model()
    params = model.init(jax.random.PRNGKey(0))
    ec = api.EngineConfig(slots=2, max_len=32, page_size=8, tune=True)

    def run_one(engine):
        engine.submit(api.Request(
            uid=0, prompt=np.arange(1, 5, dtype=np.int32),
            sampling=api.SamplingParams(max_new_tokens=4)))
        return {r.uid: r.out_tokens for r in engine.run(params, max_steps=500)}

    cache1 = CompilationCache(disk_dir=tmp_path)
    eng1 = api.ServingEngine(model, ec, compile_cache=cache1)
    out1 = run_one(eng1)
    assert cache1.stats.tuned_misses > 0 and cache1.stats.tuned_hits == 0
    assert not [e for e in eng1.events() if e["event"] == "tuned_replay"]

    # feed the DB next to the cache from the engine's own compile records
    db = TuningDB(dir=tmp_path)
    for name, rec in eng1.compile_records().items():
        assert rec.ir_fingerprint, name
        db.record(rec.ir_fingerprint, rec.hw_fingerprint, ec.backend,
                  ec.interpret, tilings=rec.tilings,
                  block_backends=rec.block_backends, measured_s=1e-4,
                  source="test", workload=name)
    assert len(db) > 0

    # a second engine (fresh cache instance = new process) replays tuned
    cache2 = CompilationCache(disk_dir=tmp_path)
    eng2 = api.ServingEngine(model, ec, compile_cache=cache2)
    out2 = run_one(eng2)
    assert out2 == out1, "tuned replay must not change tokens"
    assert cache2.stats.tuned_hits > 0
    events = [e for e in eng2.events() if e["event"] == "tuned_replay"]
    assert events, "tuned bucket compiles must announce themselves"
    for e in events:
        assert e["candidate"] and e["source"] == "test"
        assert e["measured_s"] == 1e-4


def test_engine_tune_off_never_touches_the_db(tmp_path):
    cfg, model = _tiny_engine_model()
    params = model.init(jax.random.PRNGKey(0))
    cache = CompilationCache(disk_dir=tmp_path)
    eng = api.ServingEngine(
        model, api.EngineConfig(slots=2, max_len=32, page_size=8),
        compile_cache=cache)
    eng.submit(api.Request(uid=0, prompt=np.arange(1, 5, dtype=np.int32),
                           sampling=api.SamplingParams(max_new_tokens=4)))
    eng.run(params, max_steps=500)
    assert cache.stats.tuned_hits == 0 and cache.stats.tuned_misses == 0
    assert not (tmp_path / "tuning_db.json").exists()
