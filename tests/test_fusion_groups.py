"""Fusion-group tests: whole-chain single-kernel Pallas lowering,
cost-arbitrated (VMEM-pressure-aware) group formation with an auditable
decision trace, and property-style equivalence of fused lowering with the
reference interpreter on randomized elementwise-chain + contraction
programs (both jnp and interpret-mode Pallas backends)."""
import copy

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TileProgram, execute_reference, stripe_jit
from repro.core.hwconfig import HardwareConfig, MemoryUnit, TPU_V5E
from repro.core.passes import get_pass


def _chain_prog(with_second_mm=False, m=16, k=12, n=24, n2=8):
    tp = TileProgram("chain")
    tp.input("A", (m, k))
    tp.input("B", (k, n))
    tp.input("b", (n,))
    tp.temp("T", (m, n))
    tp.temp("U", (m, n))
    if with_second_mm:
        tp.input("W2", (n, n2))
        tp.temp("G", (m, n))
        tp.output("O", (m, n2))
    else:
        tp.output("G", (m, n))
    tp.op("T[i, j] += A[i, c] * B[c, j]", name="mm1")
    tp.op("U[i, j] = T[i, j] + b[j]", name="bias")
    tp.op("G[i, j] = gelu(U[i, j])", name="act")
    if with_second_mm:
        tp.op("O[i, k2] += G[i, j] * W2[j, k2]", name="mm2")
    return tp.build()


def _rand_inputs(prog, seed=0):
    rng = np.random.RandomState(seed)
    return {n: rng.randn(*prog.buffers[n].shape).astype(np.float32)
            for n in prog.inputs}


# ------------------------------------------------------- single-kernel chain
def test_chain_lowered_as_single_pallas_kernel():
    """matmul->bias->gelu compiles to ONE pallas_call with zero
    materialized intermediates (the acceptance bar from §2.3)."""
    prog = _chain_prog()
    src = copy.deepcopy(prog)
    compiled = stripe_jit(prog, TPU_V5E, backend="pallas", interpret=True)
    assert compiled.record.backend == "pallas", compiled.record.fallback_reason
    assert compiled.record.n_kernels == 1
    assert compiled.record.groups == [["mm1", "bias", "act"]]
    # intermediates scalarized away: not in the optimized program's buffers
    assert "T" not in compiled.program.buffers
    assert "U" not in compiled.program.buffers
    ins = _rand_inputs(src)
    got = compiled(ins)["G"]
    want = execute_reference(src, ins)["G"]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


def test_two_anchor_chain_lowers_one_kernel_per_group():
    prog = _chain_prog(with_second_mm=True)
    src = copy.deepcopy(prog)
    compiled = stripe_jit(prog, TPU_V5E, backend="pallas", interpret=True)
    assert compiled.record.backend == "pallas", compiled.record.fallback_reason
    assert compiled.record.n_kernels == 2  # [mm1+bias+act], [mm2]
    ins = _rand_inputs(src, 1)
    got = compiled(ins)["O"]
    want = execute_reference(src, ins)["O"]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


def test_diamond_multi_consumer_single_kernel():
    """A multi-consumer broadcast (relu/sigmoid arms rejoining) is
    absorbed atomically into the contraction anchor."""
    tp = TileProgram("diamond")
    tp.input("A", (8, 6))
    tp.input("B", (6, 16))
    tp.temp("T", (8, 16))
    tp.temp("U", (8, 16))
    tp.temp("V", (8, 16))
    tp.output("O", (8, 16))
    tp.op("T[i, j] += A[i, c] * B[c, j]", name="mm")
    tp.op("U[i, j] = relu(T[i, j])", name="r")
    tp.op("V[i, j] = sigmoid(T[i, j])", name="s")
    tp.op("O[i, j] = U[i, j] * V[i, j]", name="join")
    prog = tp.build()
    src = copy.deepcopy(prog)
    compiled = stripe_jit(prog, TPU_V5E, backend="pallas", interpret=True)
    assert compiled.record.backend == "pallas", compiled.record.fallback_reason
    assert compiled.record.n_kernels == 1
    for buf in ("T", "U", "V"):
        assert buf not in compiled.program.buffers
    ins = _rand_inputs(src, 2)
    np.testing.assert_allclose(
        np.asarray(compiled(ins)["O"]), execute_reference(src, ins)["O"],
        rtol=1e-4, atol=1e-5)


def test_prologue_inlined_into_contraction():
    """An elementwise producer feeding only a contraction is inlined as a
    prologue (input transformed tile-by-tile inside the kernel)."""
    tp = TileProgram("pro")
    tp.input("X", (8, 12))
    tp.input("W", (12, 16))
    tp.temp("X2", (8, 12))
    tp.output("O", (8, 16))
    tp.op("X2[i, c] = gelu(X[i, c])", name="pre")
    tp.op("O[i, j] += X2[i, c] * W[c, j]", name="mm")
    prog = tp.build()
    src = copy.deepcopy(prog)
    compiled = stripe_jit(prog, TPU_V5E, backend="pallas", interpret=True)
    assert compiled.record.backend == "pallas", compiled.record.fallback_reason
    assert compiled.record.n_kernels == 1
    assert compiled.record.groups == [["pre", "mm"]]
    assert "X2" not in compiled.program.buffers
    decisions = compiled.record.fusion_decisions()
    assert any(d["kind"] == "prologue" and d["accepted"] for d in decisions)
    ins = _rand_inputs(src, 3)
    np.testing.assert_allclose(
        np.asarray(compiled(ins)["O"]), execute_reference(src, ins)["O"],
        rtol=1e-4, atol=1e-5)


def test_permuted_consumer_not_fused_and_stays_correct():
    """A consumer reading the intermediate with permuted indices
    (U = relu(T^T)) must NOT join the group — the Pallas emitter stores
    the accumulator tile interior unpermuted — and both backends must
    still produce the transposed-correct result via the unfused path."""
    tp = TileProgram("perm")
    tp.input("A", (16, 8))
    tp.input("B", (8, 16))
    tp.temp("T", (16, 16))
    tp.output("U", (16, 16))
    tp.op("T[i, j] += A[i, c] * B[c, j]", name="mm")
    tp.op("U[i, j] = relu(T[j, i])", name="tr")
    prog = tp.build()
    src = copy.deepcopy(prog)
    want = execute_reference(src, _rand_inputs(src, 7))
    ins = _rand_inputs(src, 7)
    for backend in ("jnp", "pallas"):
        compiled = stripe_jit(copy.deepcopy(src), TPU_V5E, backend=backend,
                              interpret=True, use_disk=False)
        assert compiled.record.groups == [["mm"], ["tr"]]
        np.testing.assert_allclose(
            np.asarray(compiled(ins)["U"]), want["U"], rtol=1e-4, atol=1e-5)
        decisions = compiled.record.fusion_decisions()
        assert any("permutes the group axes" in d["reason"] for d in decisions)


# ------------------------------------------------------- cost arbitration
TINY_VMEM = HardwareConfig(
    name="tiny_vmem",
    mem_units=(
        MemoryUnit("HBM", 1 << 30, 100e9, cache_line_elems=128),
        MemoryUnit("VMEM", 384 * 1024, 1e12, cache_line_elems=128),
    ),
    peak_flops=1e12,
    passes=(("fuse", {"mem_cap_frac": 0.5, "canonical_tile": 64}),),
)


def _pressure_prog():
    tp = TileProgram("pressure")
    tp.input("A", (128, 128))
    tp.input("B", (128, 128))
    tp.input("E", (128, 128))
    tp.input("F", (128, 128))
    tp.temp("T", (128, 128))
    tp.temp("U", (128, 128))
    tp.output("O", (128, 128))
    tp.op("T[i, j] += A[i, c] * B[c, j]", name="mm")
    tp.op("U[i, j] = relu(T[i, j])", name="r")
    tp.op("O[i, j] = U[i, j] + E[i, j] * F[i, j]", name="wide")
    return tp.build()


def test_vmem_pressure_rejects_unprofitable_merge():
    """Group formation is cost-arbitrated: the cheap relu merge is
    accepted, but the member dragging two extra full-tile inputs blows
    the VMEM arena budget and is rejected — and both decisions land in
    the pass report."""
    prog = _pressure_prog()
    src = copy.deepcopy(prog)
    report = []
    fused = get_pass("fuse")(prog, TINY_VMEM,
                             {"mem_cap_frac": 0.5, "canonical_tile": 64,
                              "_report": report})
    blocks = [s for s in fused.entry.stmts if hasattr(s, "tags")]
    assert len(blocks) == 2  # fused(mm+r) stays separate from `wide`
    assert any("fused" in b.tags for b in blocks)
    by_member = {d["member"]: d for d in report}
    assert by_member["r"]["accepted"] is True
    wide = by_member["wide"]
    assert wide["accepted"] is False
    assert "arena" in wide["reason"]
    assert wide["vmem_bytes"] > wide["vmem_cap"]
    # semantics unchanged by the partial fusion
    ins = _rand_inputs(src, 4)
    ra = execute_reference(src, ins)["O"]
    rb = execute_reference(fused, ins)["O"]
    np.testing.assert_allclose(ra, rb, rtol=1e-5)


def test_fusion_decisions_recorded_in_stripe_jit_trace():
    prog = _chain_prog()
    compiled = stripe_jit(prog, TPU_V5E, backend="jnp")
    decisions = compiled.record.fusion_decisions()
    assert decisions, "fuse pass must report its merge decisions"
    accepted = [d for d in decisions if d["accepted"]]
    assert {d["member"] for d in accepted} >= {"bias", "act"}
    for d in decisions:
        assert {"group", "member", "kind", "accepted", "reason"} <= set(d)


# ------------------------------------------------------- property testing
_UNARY_OPS = ["relu", "tanh", "sigmoid", "gelu", "exp", "abs"]


def _rand_chain_prog(m, k, n, ops, with_bias):
    tp = TileProgram("p")
    tp.input("A", (m, k))
    tp.input("B", (k, n))
    if with_bias:
        tp.input("b", (n,))
    tp.temp("T0", (m, n))
    tp.op("T0[i, j] += A[i, c] * B[c, j]", name="anchor")
    cur = "T0"
    for idx, op in enumerate(ops):
        nxt = f"T{idx + 1}"
        expr = f"{op}({cur}[i, j])"
        if idx == 0 and with_bias:
            expr = f"{op}({cur}[i, j] + b[j])"
        if idx == len(ops) - 1:
            tp.output("Y", (m, n))
            tp.op(f"Y[i, j] = {expr}", name=f"e{idx}")
        else:
            tp.temp(nxt, (m, n))
            tp.op(f"{nxt}[i, j] = {expr}", name=f"e{idx}")
            cur = nxt
    return tp.build()


@settings(max_examples=8, deadline=None)
@given(
    st.integers(2, 6), st.integers(2, 5), st.integers(2, 6),
    st.integers(1, 3), st.integers(0, len(_UNARY_OPS) - 1), st.integers(0, 1),
)
def test_property_fused_chain_matches_reference(m, k, n, chain_len, op0, bias):
    ops = [_UNARY_OPS[(op0 + i) % len(_UNARY_OPS)] for i in range(chain_len)]
    prog = _rand_chain_prog(m, k, n, ops, bool(bias))
    src = copy.deepcopy(prog)
    ins = _rand_inputs(src, seed=m * 1000 + k * 100 + n * 10 + chain_len)
    want = execute_reference(src, ins)["Y"]
    for backend in ("jnp", "pallas"):
        compiled = stripe_jit(copy.deepcopy(src), TPU_V5E, backend=backend,
                              interpret=True, use_disk=False)
        if backend == "pallas":
            assert compiled.record.backend == "pallas", compiled.record.fallback_reason
            assert compiled.record.n_kernels == 1
        got = np.asarray(compiled(ins)["Y"])
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-4)
