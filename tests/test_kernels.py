"""Per-kernel validation: sweep shapes/dtypes and assert_allclose against
the pure-jnp oracle (pallas interpret mode on CPU)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import attention_ref, flash_attention
from repro.kernels.mlstm_chunk.ops import chunked_gla, gla_ref, mlstm_chunk, mlstm_ref
from repro.kernels.ssd_chunk.ops import ssd_chunk, ssd_ref
from repro.kernels.stripe_matmul.ops import matmul, matmul_ref


# ------------------------------------------------------------ stripe_matmul
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 384), (64, 96, 32), (512, 256, 128)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_stripe_matmul_shapes(m, k, n, dtype):
    rng = np.random.RandomState(m + n)
    x = jnp.asarray(rng.randn(m, k), dtype)
    w = jnp.asarray(rng.randn(k, n), dtype)
    got = matmul(x, w, interpret=True)
    want = matmul_ref(x, w)
    tol = 1e-4 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("act", [None, "relu", "tanh", "silu", "square"])
def test_stripe_matmul_fused_epilogue(act):
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(128, 256), jnp.float32)
    w = jnp.asarray(rng.randn(256, 128), jnp.float32)
    b = jnp.asarray(rng.randn(128), jnp.float32)
    got = matmul(x, w, b, act=act, interpret=True)
    want = matmul_ref(x, w, b, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-4)


def test_stripe_matmul_kernel_is_generated_from_ir():
    """The kernel builder runs the actual pass pipeline: check its IR."""
    from repro.kernels.stripe_matmul.kernel import describe_kernel

    text = describe_kernel(256, 512, 384)
    assert "#mxu" in text and "#grid" in text and "VMEM" in text


# --------------------------------------------------------- flash_attention
@pytest.mark.parametrize("s,d,bq,bk", [(128, 64, 64, 64), (256, 64, 128, 64), (256, 128, 64, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(s, d, bq, bk, causal):
    rng = np.random.RandomState(s + d)
    q = jnp.asarray(rng.randn(2, 4, s, d) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(2, 4, s, d) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(2, 4, s, d) * 0.5, jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk, interpret=True)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_gqa():
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(2, 8, 128, 64) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(2, 2, 128, 64) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(2, 2, 128, 64) * 0.5, jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.randn(1, 2, 128, 64) * 0.5, jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 2, 128, 64) * 0.5, jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 2, 128, 64) * 0.5, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


def test_flash_attention_stripe_chooses_blocks():
    from repro.kernels.flash_attention.ops import choose_block_sizes

    bq, bk = choose_block_sizes(4096, 4096, 128)
    assert bq >= 128 and bk >= 128
    assert 4096 % bq == 0 and 4096 % bk == 0


# ------------------------------------------------------------- mlstm_chunk
@pytest.mark.parametrize("s,chunk", [(64, 16), (128, 32), (128, 128)])
def test_mlstm_chunk_matches_recurrence(s, chunk):
    rng = np.random.RandomState(s + chunk)
    B, H, Dk, Dv = 2, 2, 32, 32
    q = jnp.asarray(rng.randn(B, H, s, Dk) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, H, s, Dk) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, s, Dv) * 0.5, jnp.float32)
    ig = jnp.asarray(rng.randn(B, H, s) * 0.5, jnp.float32)
    fg = jnp.asarray(rng.randn(B, H, s) * 0.5 + 2.0, jnp.float32)
    got = mlstm_chunk(q, k, v, ig, fg, chunk=chunk, interpret=True)
    want = mlstm_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("normalize", [True, False])
def test_gla_generic(normalize):
    rng = np.random.RandomState(11)
    B, H, S, Dk, Dv = 1, 2, 64, 16, 24
    q = jnp.asarray(rng.randn(B, H, S, Dk) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, H, S, Dk) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, S, Dv) * 0.5, jnp.float32)
    ld = jnp.asarray(-np.abs(rng.randn(B, H, S)) * 0.2, jnp.float32)
    g = jnp.asarray(np.abs(rng.randn(B, H, S)) * 0.5, jnp.float32)
    got = chunked_gla(q, k, v, ld, g, chunk=16, normalize=normalize, interpret=True)
    want = gla_ref(q, k, v, ld, g, normalize=normalize)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------- ssd_chunk
@pytest.mark.parametrize("s,p,n", [(64, 16, 8), (128, 32, 16)])
def test_ssd_chunk_matches_recurrence(s, p, n):
    rng = np.random.RandomState(s)
    B, H = 2, 2
    x = jnp.asarray(rng.randn(B, H, s, p) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B, H, s)) * 0.3, jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(H)), jnp.float32)
    Bm = jnp.asarray(rng.randn(B, H, s, n) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, H, s, n) * 0.5, jnp.float32)
    D = jnp.asarray(rng.randn(H), jnp.float32)
    got = ssd_chunk(x, dt, A, Bm, Cm, D, chunk=32, interpret=True)
    want = ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_ssd_no_skip_connection():
    rng = np.random.RandomState(13)
    B, H, S, P, N = 1, 2, 64, 16, 8
    x = jnp.asarray(rng.randn(B, H, S, P) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B, H, S)) * 0.3, jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(H)), jnp.float32)
    Bm = jnp.asarray(rng.randn(B, H, S, N) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, H, S, N) * 0.5, jnp.float32)
    got = ssd_chunk(x, dt, A, Bm, Cm, None, chunk=16, interpret=True)
    want = ssd_ref(x, dt, A, Bm, Cm, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- oplib
def test_oplib_backends_agree():
    from repro.core import oplib

    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(64, 128), jnp.float32)
    w = jnp.asarray(rng.randn(128, 96), jnp.float32)
    b = jnp.asarray(rng.randn(96), jnp.float32)
    old = oplib.get_backend()
    try:
        oplib.set_backend("jnp")
        a = oplib.linear(x, w, b, act="relu")
        oplib.set_backend("pallas_interpret")
        c = oplib.linear(x, w, b, act="relu")
    finally:
        oplib.set_backend(old)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-4, atol=1e-4)
