"""repro.obs: span tracing (nesting, thread-safety, Chrome-trace schema),
metrics registry determinism, profiled-compile residual logging, and the
back-compat shims (``cache_stats()`` fields, engine ``metrics()`` dict)."""
import json
import threading
import time

import numpy as np
import pytest

from repro import configs, obs
from repro.core import cache as stripe_cache
from repro.core.driver import stripe_jit
from repro.core.hwconfig import get_config
from repro.models.build import build_model
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.__main__ import main as obs_cli
from repro.serving import EngineConfig, Request, SamplingParams, ServingEngine


@pytest.fixture()
def tracer():
    """A fresh, enabled tracer installed as the process default."""
    saved = obs_trace.get_tracer()
    t = obs_trace.Tracer(enabled=True)
    obs_trace.set_tracer(t)
    yield t
    obs_trace.set_tracer(saved)


def _matmul_prog():
    from repro.core.frontend import single_op_program
    return single_op_program(
        "C[i, j] += A[i, k] * B[k, j]",
        {"A": ((32, 16), "float32"), "B": ((16, 24), "float32"),
         "C": ((32, 24), "float32")}, out="C")


# ---------------------------------------------------------------- tracing
def test_span_nesting_and_attrs(tracer):
    with obs_trace.span("outer", kind="a"):
        with obs_trace.span("inner") as sp:
            sp.set(extra=7)
    recs = {r.name: r for r in tracer.spans()}
    assert set(recs) == {"outer", "inner"}
    assert recs["inner"].depth == recs["outer"].depth + 1
    assert recs["inner"].parent == "outer"
    assert recs["inner"].attrs["extra"] == 7
    assert recs["outer"].ts <= recs["inner"].ts
    assert (recs["inner"].ts + recs["inner"].dur
            <= recs["outer"].ts + recs["outer"].dur + 1e-9)


def test_span_records_exceptions(tracer):
    with pytest.raises(ValueError):
        with obs_trace.span("boom"):
            raise ValueError("x")
    (rec,) = tracer.spans()
    assert "error" in rec.attrs


def test_spans_disabled_are_free():
    saved = obs_trace.get_tracer()
    t = obs_trace.Tracer(enabled=False)
    obs_trace.set_tracer(t)
    try:
        with obs_trace.span("nope"):
            pass
        obs_trace.instant("nope2")
        assert t.spans() == []
    finally:
        obs_trace.set_tracer(saved)


def test_span_thread_safety(tracer):
    """Concurrent spans from many threads land without loss and keep
    per-thread nesting (the serving prep thread does exactly this)."""
    n_threads, n_spans = 8, 50

    def worker(i):
        for j in range(n_spans):
            with obs_trace.span(f"w{i}", j=j):
                pass

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = tracer.spans()
    assert len(recs) == n_threads * n_spans
    assert all(r.depth == 0 for r in recs)  # no cross-thread nesting


def test_ring_buffer_bounds_spans():
    t = obs_trace.Tracer(capacity=10, enabled=True)
    for i in range(25):
        with t.span(f"s{i}"):
            pass
    assert len(t.spans()) == 10
    assert t.dropped == 15


def test_chrome_trace_schema(tracer, tmp_path):
    with obs_trace.span("phase.one", tag=1):
        obs_trace.instant("marker")
    now = time.perf_counter()
    obs_trace.span_at("retro", now - 0.25, now, uid=3)
    path = tmp_path / "trace.json"
    obs_trace.get_tracer().export_chrome_trace(str(path))
    doc = json.loads(path.read_text())
    assert set(doc) >= {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    phs = {e["ph"] for e in evs}
    assert "X" in phs and "i" in phs and "M" in phs
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["ts"] >= 0
    names = {e["name"] for e in evs if e["ph"] in ("X", "i")}
    assert {"phase.one", "marker", "retro"} <= names


def test_cli_summarize(tracer, tmp_path, capsys):
    with obs_trace.span("pass.fuse"):
        pass
    t0 = time.perf_counter()
    obs_trace.span_at("serve.request", t0 - 0.5, t0, uid=0, status="ok",
                      tokens=4)
    obs_trace.span_at("serve.queue", t0 - 0.5, t0 - 0.4, uid=0)
    obs_trace.span_at("serve.prefill", t0 - 0.4, t0 - 0.3, uid=0)
    path = tmp_path / "t.json"
    obs_trace.get_tracer().export_chrome_trace(str(path))
    assert obs_cli(["summarize", str(path), "--requests"]) == 0
    out = capsys.readouterr().out
    assert "pass.fuse" in out and "serve.request" in out
    assert "queue" in out  # the per-request breakdown rendered


def test_request_breakdown():
    events = [
        {"name": "serve.request", "ph": "X", "ts": 0.0, "dur": 1_000_000.0,
         "pid": 1, "tid": 1, "args": {"uid": 5, "status": "ok"}},
        {"name": "serve.queue", "ph": "X", "ts": 0.0, "dur": 300_000.0,
         "pid": 1, "tid": 1, "args": {"uid": 5}},
        {"name": "serve.prefill", "ph": "X", "ts": 300_000.0,
         "dur": 200_000.0, "pid": 1, "tid": 1, "args": {"uid": 5}},
    ]
    per = obs_trace.request_breakdown(events)
    assert per[5]["queue_s"] == pytest.approx(0.3)
    assert per[5]["prefill_s"] == pytest.approx(0.2)
    assert per[5]["decode_s"] == pytest.approx(0.5)
    assert per[5]["total_s"] == pytest.approx(1.0)


# ---------------------------------------------------------------- metrics
def test_metrics_snapshot_deterministic():
    reg = obs_metrics.Registry()
    reg.counter("b.count", route="y").inc(2)
    reg.counter("a.count").inc()
    reg.gauge("a.gauge").set(1.5)
    for v in (0.001, 0.002, 0.004, 0.1):
        reg.histogram("lat").observe(v)
    s1, s2 = reg.snapshot(), reg.snapshot()
    assert s1 == s2
    assert list(s1["counters"]) == sorted(s1["counters"])
    assert s1["counters"]["a.count"] == 1
    assert s1["counters"]["b.count{route=y}"] == 2
    h = s1["histograms"]["lat"]
    assert h["count"] == 4
    assert h["min"] == pytest.approx(0.001)
    assert h["max"] == pytest.approx(0.1)
    assert h["sum"] == pytest.approx(0.107)
    assert 0.001 <= h["p50"] <= h["p99"] <= 0.2 + 1e-9


def test_metrics_type_conflict():
    reg = obs_metrics.Registry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_metrics_thread_safety():
    reg = obs_metrics.Registry()
    c = reg.counter("n")

    def bump():
        for _ in range(10_000):
            c.inc()

    threads = [threading.Thread(target=bump) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 40_000


# -------------------------------------------------- cache_stats back-compat
def test_cache_stats_shim_back_compat():
    stats = stripe_cache.CacheStats()
    assert stats.hits == 0
    stats.hits += 3
    stats.misses = 2
    assert (stats.hits, stats.misses) == (3, 2)
    d = stats.as_dict()
    assert d["hits"] == 3 and d["misses"] == 2
    assert set(d) == set(stripe_cache.CacheStats.FIELDS)
    # the counters live in a real registry
    snap = stats.registry.snapshot()
    assert snap["counters"]["cache.hits"] == 3


def test_cache_stats_counts_real_traffic(tmp_path):
    cache = stripe_cache.CompilationCache(disk_dir=str(tmp_path))
    hw = get_config("cpu_test")
    stripe_jit(_matmul_prog(), hw, backend="jnp", cache=cache)
    stripe_jit(_matmul_prog(), hw, backend="jnp", cache=cache)
    assert cache.stats.misses >= 1 and cache.stats.hits >= 1


# ------------------------------------------------------- profiled compiles
def test_profiled_compile_residuals(tmp_path):
    cache = stripe_cache.CompilationCache(disk_dir=str(tmp_path))
    hw = get_config("cpu_test")
    compiled = stripe_jit(_matmul_prog(), hw, backend="jnp", cache=cache,
                          profile=True)
    rec = compiled.record
    assert rec.profiled
    assert rec.predicted_latency_s  # cost model ran
    rng = np.random.RandomState(0)
    ins = {"A": rng.randn(32, 16).astype(np.float32),
           "B": rng.randn(16, 24).astype(np.float32)}
    compiled(ins)
    assert rec.measured_latency_s
    assert all(v > 0 for v in rec.measured_latency_s.values())
    res = rec.latency_residuals()
    assert res and {"block", "predicted_s", "measured_s"} <= set(res[0])
    rows = obs.read_residuals(obs.residual_log_path(cache))
    assert rows, "profiled dispatch must append residual rows"
    for row in rows:
        assert row["measured_s"] > 0
        assert row["ir_fingerprint"] and row["hw_fingerprint"]
    summ = obs.summarize_residuals(rows)
    assert summ["rows"] == len(rows)
    assert summ["pairs_with_prediction"] >= 1
    # a profiled compile must not be served from the unprofiled cache line
    plain = stripe_jit(_matmul_prog(), hw, backend="jnp", cache=cache)
    assert not plain.record.profiled


def test_compile_spans_emitted(tmp_path, tracer):
    cache = stripe_cache.CompilationCache(disk_dir=str(tmp_path))
    stripe_jit(_matmul_prog(), get_config("cpu_test"), backend="jnp",
               cache=cache)
    names = [r.name for r in tracer.spans()]
    assert "compile.stripe_jit" in names
    assert any(n.startswith("pass.") for n in names)
    assert "cache.probe" in names


# --------------------------------------------------------- serving engine
def _tiny_model():
    cfg = configs.get("llama3-8b").scaled(
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
        head_dim=16, vocab_pad_multiple=16)
    return cfg, build_model(cfg)


def _run_requests(eng, cfg, params, n=4, base_uid=0):
    r = np.random.RandomState(0)
    for i in range(n):
        eng.submit(Request(uid=base_uid + i,
                           prompt=r.randint(1, cfg.vocab, size=5).astype(np.int32),
                           sampling=SamplingParams(max_new_tokens=4)))
    return eng.run(params, max_steps=10_000)


def test_engine_metrics_shim_and_registry(tracer):
    import jax
    cfg, model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, EngineConfig(slots=2, max_len=32, page_size=8))
    done = _run_requests(eng, cfg, params)
    assert len(done) == 4

    m = eng.metrics()  # legacy dict shape, plus dropped_events
    for key in ("decode_steps", "tokens_out", "finished", "slot_utilization",
                "queue_depth", "dropped_events"):
        assert key in m
    assert m["finished"] == 4 and m["dropped_events"] == 0

    snap = eng.metrics_snapshot()
    assert snap["counters"]["serve.tokens_out"] == m["tokens_out"]
    assert snap["counters"]["serve.finished{status=ok}"] == 4
    assert snap["counters"]["serve.events{event=admit}"] == 4
    assert snap["histograms"]["serve.request_s"]["count"] == 4
    assert snap["histograms"]["serve.decode_step_s"]["count"] == m["decode_steps"]
    assert snap["histograms"]["serve.queue_wait_s"]["count"] == 4
    assert snap["histograms"]["serve.prefill_s"]["count"] == 4

    # request-lifecycle spans: queue + prefill + request per uid, decode steps
    names = [r.name for r in tracer.spans()]
    assert names.count("serve.request") == 4
    assert names.count("serve.queue") == 4
    assert names.count("serve.prefill") == 4
    assert "serve.decode_step" in names
    assert "serve.prep" in names  # recorded on the prep thread


def test_engine_event_ring_buffer():
    import jax
    cfg, model = _tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, EngineConfig(slots=2, max_len=32, page_size=8,
                                            event_log_size=5))
    _run_requests(eng, cfg, params, n=4)
    assert len(eng.events()) == 5
    assert eng.metrics()["dropped_events"] > 0
    # the registry still counted every event, drops notwithstanding
    snap = eng.metrics_snapshot()
    assert snap["counters"]["serve.events{event=finish}"] == 4
