"""Chaos tests for the serving engine's resilience layer: prep-thread
supervision, compile quarantine with jnp-fallback serving, crash-safe
decode-step retry with exactly-once output, deadlines, bounded-queue load
shedding under overload, and page-allocation failures."""
import threading
import time

import numpy as np
import pytest

from repro import configs
from repro.models.build import build_model
from repro.reliability import faults
from repro.serving import EngineConfig, Request, SamplingParams, ServingEngine


def _tiny_cfg():
    return configs.get("llama3-8b").scaled(n_layers=2, d_model=32, n_heads=2,
                                           n_kv_heads=2, d_ff=64, vocab=64,
                                           head_dim=16, vocab_pad_multiple=16)


@pytest.fixture(scope="module")
def model():
    return build_model(_tiny_cfg())


@pytest.fixture(scope="module")
def params(model):
    import jax
    return model.init(jax.random.PRNGKey(0))


def _mk_requests(cfg, plens, new=6, base_uid=0, seed=3, **samp):
    r = np.random.RandomState(seed)
    return [Request(uid=base_uid + i,
                    prompt=r.randint(1, cfg.vocab, size=p).astype(np.int32),
                    sampling=SamplingParams(max_new_tokens=new, **samp))
            for i, p in enumerate(plens)]


def _engine(model, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("page_size", 8)
    return ServingEngine(model, EngineConfig(**kw))


def _baseline_tokens(model, params, plens, new=6, seed=3):
    eng = _engine(model)
    for r in _mk_requests(model.cfg, plens, new=new, seed=seed):
        eng.submit(r)
    done = eng.run(params, max_steps=4096)
    return {r.uid: list(r.out_tokens) for r in done}


def _events_of(eng, name):
    return [e for e in eng.events() if e["event"] == name]


# ------------------------------------------------------ prep supervision
def test_prep_item_fault_fails_request_thread_survives(model, params):
    with faults.inject(faults.fail_nth("serve.prep", 2)):
        eng = _engine(model)
        for r in _mk_requests(model.cfg, [4, 7, 9, 5]):
            eng.submit(r)
        done = eng.run(params, max_steps=4096)
    by_uid = {r.uid: r for r in done}
    assert sorted(by_uid) == [0, 1, 2, 3], "every request must reach a terminal state"
    assert by_uid[1].status == "failed"
    assert "InjectedFault" in by_uid[1].error
    assert all(by_uid[u].status == "ok" and by_uid[u].out_tokens
               for u in (0, 2, 3))
    assert _events_of(eng, "prep_failed")
    # the worker survived: the engine keeps serving
    eng.submit(_mk_requests(model.cfg, [6], base_uid=10)[0])
    done2 = eng.run(params, max_steps=4096)
    assert done2 and done2[0].status == "ok"


def test_prep_thread_death_detected_fast_and_restarted(model, params):
    # regression for the old 10s-timeout stall: a dying worker must hand
    # its exception back under the condition variable, immediately
    with faults.inject(faults.fail_nth("serve.prep_thread", 1)):
        eng = _engine(model)
        for r in _mk_requests(model.cfg, [4, 7, 9]):
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run(params, max_steps=4096)
        detect = time.perf_counter() - t0
    assert detect < 5.0, f"thread death took {detect:.1f}s to surface (old bug: 10s stall)"
    by_uid = {r.uid: r for r in done}
    assert sorted(by_uid) == [0, 1, 2]
    # prep is side-effect-free: the in-flight request is requeued through
    # the restarted worker and completes like everything else
    assert all(by_uid[u].status == "ok" and by_uid[u].out_tokens
               for u in (0, 1, 2))
    assert by_uid[0].retries == 1
    restarts = _events_of(eng, "prep_thread_restart")
    assert restarts and "InjectedFault" in restarts[0]["error"], \
        "worker's exception must be attached to the restart event"
    assert restarts[0]["requeued_uid"] == 0
    assert eng.metrics()["prep_restarts"] == 1


def test_prep_thread_death_retries_exhausted_fails_request(model, params):
    # a request that kills the worker every time it is prepped burns its
    # retry budget and fails; the engine stays up
    rule = faults.fail_when("serve.prep_thread",
                            lambda ctx: ctx["uid"] == 1)
    rule.times = None
    with faults.inject(rule):
        eng = _engine(model, max_retries=1)
        for r in _mk_requests(model.cfg, [4, 7, 9]):
            eng.submit(r)
        done = {r.uid: r for r in eng.run(params, max_steps=4096)}
    assert done[1].status == "failed"
    assert "InjectedFault" in done[1].error
    assert done[0].status == "ok" and done[2].status == "ok"
    assert eng.metrics()["prep_restarts"] == 2  # initial try + 1 retry


# ------------------------------------------------- crash-safe decode step
def test_decode_step_crash_replays_exactly_once(model, params):
    plens = [3, 8, 13, 5]
    want = _baseline_tokens(model, params, plens)
    with faults.inject(faults.fail_nth("serve.decode_step", 3)):
        eng = _engine(model)
        streamed = []
        reqs = _mk_requests(model.cfg, plens)
        for r in reqs:
            eng.submit(r)
        for uid, tok in eng.generate([], params=params):
            streamed.append((uid, tok))
        done = {r.uid: r for r in reqs}
    assert _events_of(eng, "device_step_failed")
    assert _events_of(eng, "requeue")
    for uid, toks in want.items():
        assert list(done[uid].out_tokens) == toks, \
            f"uid {uid}: retried request diverged from fault-free run"
        assert done[uid].status == "ok"
    # exactly-once on the stream: each request's tokens appear once, in order
    for uid, toks in want.items():
        got = [t for u, t in streamed if u == uid]
        assert got == toks, f"uid {uid}: stream not exactly-once"


def test_decode_step_crash_scoped_to_payload_slots(model, params):
    plens = [4, 9, 6, 11]
    want = _baseline_tokens(model, params, plens, new=8)
    rule = faults.fail_nth("serve.decode_step", 2, payload={"slots": [0]})
    with faults.inject(rule):
        eng = _engine(model)
        for r in _mk_requests(model.cfg, plens, new=8):
            eng.submit(r)
        done = {r.uid: r for r in eng.run(params, max_steps=4096)}
    ev = _events_of(eng, "device_step_failed")
    assert ev and ev[0]["slots"] == [0], "only the scripted slot is affected"
    assert len(_events_of(eng, "requeue")) == 1
    for uid, toks in want.items():
        assert list(done[uid].out_tokens) == toks
        assert done[uid].status == "ok"


def test_retries_exhausted_fails_request_without_hanging(model, params):
    # every decode step fails: requests burn max_retries then fail; the
    # engine must converge (no infinite requeue loop)
    with faults.inject(faults.fail_every("serve.decode_step", 1, times=None)):
        eng = _engine(model, max_retries=1)
        for r in _mk_requests(model.cfg, [4, 7]):
            eng.submit(r)
        done = eng.run(params, max_steps=4096)
    assert len(done) == 2
    for r in done:
        assert r.status == "failed"
        assert "retries exhausted" in r.error
        assert len(r.out_tokens) == 1, "only the prefill token was produced"
    assert _events_of(eng, "retry_exhausted")
    assert eng.metrics()["finished_by_status"]["failed"] == 2


# ------------------------------------------------------------- deadlines
def test_queued_deadline_never_occupies_a_slot(model, params):
    eng = _engine(model, slots=1)
    r1, r2 = _mk_requests(model.cfg, [5, 6], new=8)
    eng.submit(r1)
    eng.submit(r2)
    r2.deadline = time.perf_counter() - 1.0  # already expired in the queue
    done = {r.uid: r for r in eng.run(params, max_steps=4096)}
    assert done[r2.uid].status == "deadline_exceeded"
    assert done[r2.uid].out_tokens == [], "expired queued request never ran"
    assert done[r1.uid].status == "ok"
    assert not any(e["event"] == "admit" and e["uid"] == r2.uid
                   for e in eng.events()), "expired request must not take a slot"
    ev = _events_of(eng, "deadline_exceeded")
    assert ev and ev[0]["where"] == "queued"


def test_mid_decode_deadline_evicts_with_partial_output(model, params):
    eng = _engine(model)
    (r,) = _mk_requests(model.cfg, [5], new=30)
    eng.submit(r)
    eng.run(params, max_steps=3)  # admit + a few decode steps
    assert not r.done and len(r.out_tokens) >= 1
    r.deadline = time.perf_counter() - 1.0
    done = eng.run(params, max_steps=4096)
    assert [x.uid for x in done] == [r.uid]
    assert r.status == "deadline_exceeded"
    assert 1 <= len(r.out_tokens) < 30, "partial output stands"
    ev = _events_of(eng, "deadline_exceeded")
    assert ev and ev[0]["where"] == "slot"
    assert eng.metrics()["free_pages"] == eng.config.pool_pages, \
        "evicted deadline request must release its pages"


def test_ttl_end_to_end(model, params):
    eng = _engine(model, default_ttl_s=0.001)
    for r in _mk_requests(model.cfg, [4, 6]):
        eng.submit(r)
    time.sleep(0.05)
    done = eng.run(params, max_steps=4096)
    assert len(done) == 2
    assert all(r.status == "deadline_exceeded" for r in done)


# ------------------------------------------------------ compile quarantine
def test_bucket_quarantine_serves_fallback_same_step(model, params):
    plens = [5, 6, 7]  # one bucket (8)
    want = _baseline_tokens(model, params, plens)
    with faults.inject(faults.fail_nth("serve.prefill_compile", 1)):
        eng = _engine(model, quarantine_backoff_s=30.0)
        for r in _mk_requests(model.cfg, plens):
            eng.submit(r)
        done = {r.uid: r for r in eng.run(params, max_steps=4096)}
    # the compile failed, yet every request completed with correct tokens
    # on the same serve call — degraded throughput, not degraded output
    for uid, toks in want.items():
        assert done[uid].status == "ok"
        assert list(done[uid].out_tokens) == toks
    q = _events_of(eng, "quarantine")
    assert len(q) == 1 and q[0]["bucket"] == 8 and "InjectedFault" in q[0]["reason"]
    assert any(c["kind"] == "prefill_fallback" for c in eng.compile_log())
    assert eng.metrics()["quarantined"] == 1
    assert eng.cache_stats().quarantined == 1
    assert eng.cache_stats().quarantine_hits >= 1, \
        "later admissions of the bucket must hit the embargo, not recompile"
    assert list(eng.quarantine_entries().values())[0]["fail_count"] == 1


def test_bucket_quarantine_expiry_recompiles_and_clears(model, params):
    with faults.inject(faults.fail_nth("serve.prefill_compile", 1)):
        eng = _engine(model, quarantine_backoff_s=30.0)
        for r in _mk_requests(model.cfg, [5, 6]):
            eng.submit(r)
        eng.run(params, max_steps=4096)
        assert eng.metrics()["quarantined"] == 1
        # force the embargo to lapse (deterministic, no sleep)
        for e in eng._quarantine.entries().values():
            e.until = 0.0
        for r in _mk_requests(model.cfg, [7], base_uid=10):
            eng.submit(r)
        done = eng.run(params, max_steps=4096)
    assert done[0].status == "ok"
    assert _events_of(eng, "quarantine_expired")
    assert _events_of(eng, "quarantine_clear")
    assert eng.metrics()["quarantined"] == 0
    assert eng.cache_stats().quarantine_clears == 1
    assert any(k.startswith("prefill_L8/") for k in eng.compile_records()), \
        "recovered bucket must compile through stripe for real"


# ------------------------------------------------------------ page allocs
def test_alloc_fault_defers_admission(model, params):
    with faults.inject(faults.fail_nth("paged.alloc", 1)):
        eng = _engine(model)
        for r in _mk_requests(model.cfg, [4, 9]):
            eng.submit(r)
        done = eng.run(params, max_steps=4096)
    assert all(r.status == "ok" for r in done) and len(done) == 2
    assert _events_of(eng, "alloc_failed")


# ------------------------------------------------- overload / load shedding
def test_overload_sheds_bounded_queue_no_lost_or_duplicated(model, params):
    # Satellite: open-loop feeder at ~4x the sustainable rate against a
    # bounded queue.  Sheds must happen; every admitted request finishes
    # exactly once; admitted latency stays bounded by the queue cap.
    cfg = model.cfg
    # measure sustainable throughput (warm compiles first)
    warm = _engine(model)
    for r in _mk_requests(cfg, [6] * 4, new=4):
        warm.submit(r)
    warm.run(params, max_steps=4096)
    t0 = time.perf_counter()
    for r in _mk_requests(cfg, [6] * 8, new=4, base_uid=100):
        warm.submit(r)
    warm.run(params, max_steps=4096)
    per_req = (time.perf_counter() - t0) / 8

    n, max_queue = 80, 6
    eng = _engine(model, max_queue=max_queue)
    # warm this engine's compiles so admitted latency is steady-state
    for r in _mk_requests(cfg, [6] * 2, new=4, base_uid=5000):
        eng.submit(r)
    eng.run(params, max_steps=4096)

    reqs = _mk_requests(cfg, [6] * n, new=4, seed=11)
    accepted, shed = [], []
    stop = threading.Event()

    def feeder():
        for r in reqs:
            (accepted if eng.submit(r) else shed).append(r)
            time.sleep(per_req / 4)  # 4x sustainable arrival rate
        stop.set()

    th = threading.Thread(target=feeder)
    th.start()
    finished = []
    while not stop.is_set() or any(not r.done for r in accepted):
        finished.extend(eng.run(params, max_steps=200))
    th.join()

    assert shed, "4x overload against a bounded queue must shed"
    assert len(accepted) + len(shed) == n
    fin_uids = [r.uid for r in finished if r.uid < 5000]
    assert sorted(fin_uids) == sorted(r.uid for r in accepted), \
        "every admitted request finishes; no shed request leaks in"
    assert len(fin_uids) == len(set(fin_uids)), "no duplicated completions"
    for r in accepted:
        assert r.status == "ok" and len(r.out_tokens) == 4
    assert {r.uid for r in eng.shed()} >= {r.uid for r in shed}
    assert len(_events_of(eng, "shed")) == len(shed)
    # bounded latency: an admitted request waits at most on the queue cap
    # plus the in-flight slots (generous 10x margin for scheduling noise)
    lat = sorted(r.latency for r in accepted)
    p99 = lat[int(0.99 * (len(lat) - 1))]
    bound = 10 * per_req * (max_queue + eng.slots + 2)
    assert p99 < bound, f"admitted p99 {p99:.3f}s exceeds bound {bound:.3f}s"
