"""Compilation cache + driver tests: key stability, hit/miss/evict
semantics, corrupt-entry recovery, env-var override, warm-vs-cold compile
speed, and equality of cached vs freshly-compiled results."""
import json
import time

import numpy as np
import pytest

from repro.core import (
    CompilationCache,
    execute_reference,
    ir_fingerprint,
    single_op_program,
    stripe_jit,
)
from repro.core.cache import content_key, default_cache_dir
from repro.core.driver import compile_cached
from repro.core.hwconfig import CPU_TEST, PAPER_FIG4, TPU_V5E


def _conv_prog(dtype="float32"):
    return single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), dtype), "F": ((3, 3, 8, 16), dtype),
         "O": ((12, 16, 16), dtype)},
        out="O",
    )


def _conv_arrays(seed=0):
    rng = np.random.RandomState(seed)
    return {"I": rng.randn(12, 16, 8).astype(np.float32),
            "F": rng.randn(3, 3, 8, 16).astype(np.float32)}


# ------------------------------------------------------------ key stability
def test_ir_fingerprint_stable_across_builds():
    assert ir_fingerprint(_conv_prog()) == ir_fingerprint(_conv_prog())


def test_ir_fingerprint_ignores_nonsemantic_fields():
    a, b = _conv_prog(), _conv_prog()
    # comments and tag insertion order are non-semantic
    a.entry.stmts[0].comments = "scribble"
    a.entry.stmts[0].tags = set(list(a.entry.stmts[0].tags)[::-1])
    b.entry.stmts[0].add_tag("zz_marker")
    b.entry.stmts[0].tags.discard("zz_marker")
    # buffer-dict insertion order is non-semantic
    a.buffers = dict(reversed(list(a.buffers.items())))
    assert ir_fingerprint(a) == ir_fingerprint(b)


def test_ir_fingerprint_sees_semantic_changes():
    base = ir_fingerprint(_conv_prog())
    assert ir_fingerprint(_conv_prog(dtype="bfloat16")) != base
    other = _conv_prog()
    other.entry.stmts[0].add_tag("elementwise")  # tags steer passes
    assert ir_fingerprint(other) != base


def test_hwconfig_fingerprint_distinguishes_params():
    assert CPU_TEST.fingerprint() != TPU_V5E.fingerprint()
    assert CPU_TEST.fingerprint() == CPU_TEST.fingerprint()
    tweaked = TPU_V5E.with_params(**{"autotile.search": "divisors"})
    assert tweaked.fingerprint() != TPU_V5E.fingerprint()


# --------------------------------------------------------- hit/miss/evict
def test_memory_hit_miss_evict_stats():
    c = CompilationCache(capacity=2, use_disk=False)
    assert c.get("k1") is None
    c.put("k1", "v1")
    c.put("k2", "v2")
    assert c.get("k1") == "v1" and c.get("k2") == "v2"
    c.put("k3", "v3")  # evicts LRU (k1)
    assert c.get("k1") is None
    s = c.stats
    assert s.hits == 2 and s.misses == 2 and s.evictions == 1 and s.puts == 3


def test_disk_roundtrip_and_env_override(tmp_path, monkeypatch):
    monkeypatch.setenv("STRIPE_CACHE_DIR", str(tmp_path))
    assert default_cache_dir() == tmp_path
    c = CompilationCache()
    assert c.disk_dir == tmp_path
    key = content_key("unit", 1)
    c.put_disk(key, {"tilings": {"op0": {"x": 3}}})
    assert list(tmp_path.glob("*.json")), "entry not persisted"
    # a fresh instance (= another process) reads it back
    c2 = CompilationCache()
    assert c2.get_disk(key) == {"tilings": {"op0": {"x": 3}}}
    assert c2.stats.disk_hits == 1


def test_disk_corrupt_entry_recovery(tmp_path):
    c = CompilationCache(disk_dir=tmp_path)
    key = content_key("corrupt")
    c.put_disk(key, {"v": 1})
    path = tmp_path / f"{key}.json"
    path.write_text("{ not json")
    assert c.get_disk(key) is None
    assert c.stats.disk_errors == 1
    assert not path.exists(), "corrupt entry should be deleted"
    # wrong-key (stale/moved) entries are also rejected
    other = content_key("other")
    (tmp_path / f"{other}.json").write_text(
        json.dumps({"version": 1, "key": "someone-else", "payload": {}}))
    assert c.get_disk(other) is None
    assert c.stats.disk_errors == 2


def test_disk_writes_are_atomic_no_partial_files(tmp_path):
    # writes go through a same-directory temp file + os.replace, so a
    # reader never observes a half-written entry and no temp litter stays
    c = CompilationCache(disk_dir=tmp_path)
    key = content_key("atomic")
    c.put_disk(key, {"v": list(range(1000))})
    names = [p.name for p in tmp_path.iterdir()]
    assert names == [f"{key}.json"], f"unexpected files next to the entry: {names}"
    assert c.get_disk(key) == {"v": list(range(1000))}


def test_torn_disk_write_recovered(tmp_path):
    # simulate a non-atomic writer (the cache.disk_write_torn fault site
    # truncates the payload in place): the torn entry must read as a miss,
    # count as a disk error, be deleted, and be rewritable
    from repro.reliability import faults

    c = CompilationCache(disk_dir=tmp_path)
    key = content_key("torn")
    with faults.inject(faults.fail_nth("cache.disk_write_torn", 1)):
        c.put_disk(key, {"tilings": {"op0": {"x": 3}}})
    raw = (tmp_path / f"{key}.json").read_text()
    with pytest.raises(json.JSONDecodeError):
        json.loads(raw)
    assert c.get_disk(key) is None, "torn entry must degrade to a miss"
    assert c.stats.disk_errors >= 1
    assert not (tmp_path / f"{key}.json").exists(), "torn entry must be removed"
    c.put_disk(key, {"tilings": {"op0": {"x": 3}}})
    assert c.get_disk(key) == {"tilings": {"op0": {"x": 3}}}


def test_cache_disable_env(tmp_path, monkeypatch):
    monkeypatch.setenv("STRIPE_CACHE_DISABLE", "1")
    c = CompilationCache(disk_dir=tmp_path)
    assert c.disk_dir is None
    c.put_disk("k", {"v": 1})
    assert not list(tmp_path.glob("*.json"))


# ------------------------------------------------------------------ driver
def test_stripe_jit_warm_10x_faster_than_cold(tmp_path):
    cache = CompilationCache(disk_dir=tmp_path)
    t0 = time.perf_counter()
    cold = stripe_jit(_conv_prog(), CPU_TEST, cache=cache)
    t_cold = time.perf_counter() - t0
    assert not cold.record.cache_hit and not cold.record.disk_hit
    assert cold.record.tilings, "cold compile must record tilings"

    t0 = time.perf_counter()
    warm = stripe_jit(_conv_prog(), CPU_TEST, cache=cache)
    t_warm = time.perf_counter() - t0
    assert warm.record.cache_hit
    assert not cold.record.cache_hit, "warm lookup must not mutate the cold caller's record"
    assert warm.record.tilings == cold.record.tilings
    assert t_cold >= 10 * t_warm, f"warm {t_warm:.6f}s not 10x faster than cold {t_cold:.6f}s"

    # cross-process warm: fresh cache over the same disk dir replays the
    # recorded tilings with no autotile search
    cache2 = CompilationCache(disk_dir=tmp_path)
    t0 = time.perf_counter()
    disk_warm = stripe_jit(_conv_prog(), CPU_TEST, cache=cache2)
    t_disk = time.perf_counter() - t0
    assert disk_warm.record.disk_hit and not disk_warm.record.cache_hit
    assert disk_warm.record.tilings == cold.record.tilings
    assert t_cold >= 10 * t_disk, f"disk-warm {t_disk:.6f}s not 10x faster than cold {t_cold:.6f}s"


def test_cached_results_equal_fresh_and_reference(tmp_path):
    arrays = _conv_arrays()
    ref = execute_reference(_conv_prog(), arrays)["O"]
    cache = CompilationCache(disk_dir=tmp_path)
    fresh = stripe_jit(_conv_prog(), CPU_TEST, cache=cache)
    replayed = stripe_jit(_conv_prog(), CPU_TEST, cache=CompilationCache(disk_dir=tmp_path))
    a = np.asarray(fresh(arrays)["O"])
    b = np.asarray(replayed(arrays)["O"])
    np.testing.assert_array_equal(a, b)
    np.testing.assert_allclose(a, ref, rtol=1e-4, atol=1e-5)


def test_stripe_jit_contraction_string_and_backends(tmp_path):
    cache = CompilationCache(disk_dir=tmp_path)
    tensors = {"A": ((32, 16), "float32"), "B": ((16, 24), "float32"),
               "O": ((32, 24), "float32")}
    rng = np.random.RandomState(0)
    arrays = {"A": rng.randn(32, 16).astype(np.float32),
              "B": rng.randn(16, 24).astype(np.float32)}
    want = arrays["A"] @ arrays["B"]
    for backend in ("jnp", "reference", "pallas"):
        cp = stripe_jit("O[i, j] += A[i, c] * B[c, j]", CPU_TEST, backend,
                        tensors=tensors, out="O", cache=cache)
        got = np.asarray(cp(arrays)["O"])
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_stripe_jit_rejects_bad_input():
    with pytest.raises(ValueError):
        stripe_jit("O[i] += A[i]", CPU_TEST)  # no tensors/out
    with pytest.raises(ValueError):
        stripe_jit(_conv_prog(), CPU_TEST, backend="tpu_v9")
    with pytest.raises(TypeError):
        stripe_jit(123, CPU_TEST)


def test_compile_cached_memory_hit_is_isolated_copy(tmp_path):
    cache = CompilationCache(disk_dir=tmp_path)
    prog = _conv_prog()
    opt1, rec1 = compile_cached(prog, PAPER_FIG4, cache=cache)
    assert not rec1.cache_hit
    opt2, rec2 = compile_cached(prog, PAPER_FIG4, cache=cache)
    assert rec2.cache_hit
    # mutating one caller's copy must not leak into the cache
    opt2.entry.stmts.clear()
    opt3, _ = compile_cached(prog, PAPER_FIG4, cache=cache)
    assert opt3.entry.stmts, "cache entry was mutated through a returned copy"
