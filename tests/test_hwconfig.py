"""HardwareConfig accessor, fingerprint, and mutation-helper contracts.

The design-space sweeps rest on two fingerprint invariants: equal
compilation behavior => equal fingerprint (names excluded, so renamed
sweep points dedupe into one compilation-cache entry), and any
compilation-relevant field change => different fingerprint (no
collisions across distinct configs).
"""
import dataclasses

import pytest

from repro.core import CompilationCache, compile_cached, single_op_program
from repro.core.hwconfig import REGISTRY, HardwareConfig, get_config


def _mm():
    return single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((64, 32), "float32"), "B": ((32, 48), "float32"),
         "O": ((64, 48), "float32")},
        out="O",
    )


# --------------------------------------------------------------------------
# get_config accessor
# --------------------------------------------------------------------------
def test_get_config_returns_registry_entries():
    for name in REGISTRY:
        assert get_config(name) is REGISTRY[name]


def test_get_config_unknown_lists_available():
    with pytest.raises(KeyError) as ei:
        get_config("tpu_v9000")
    msg = str(ei.value)
    assert "tpu_v9000" in msg
    for name in REGISTRY:
        assert name in msg


def test_mem_keyerror_names_config_and_units():
    hw = get_config("tpu_v5e")
    with pytest.raises(KeyError) as ei:
        hw.mem("L3")
    msg = str(ei.value)
    assert "L3" in msg and "tpu_v5e" in msg
    for unit in ("HBM", "VMEM", "VREG"):
        assert unit in msg


# --------------------------------------------------------------------------
# fingerprint: changes iff a compilation-relevant field changes
# --------------------------------------------------------------------------
def test_fingerprint_ignores_name():
    hw = get_config("tpu_v5e")
    assert hw.renamed("anything_else").fingerprint() == hw.fingerprint()


def test_fingerprint_is_stable_and_distinct_across_configs():
    fps = {name: get_config(name).fingerprint() for name in REGISTRY}
    assert len(set(fps.values())) == len(fps)
    for name in REGISTRY:
        assert get_config(name).fingerprint() == fps[name]


@pytest.mark.parametrize("mutate", [
    lambda hw: hw.with_mem("VMEM", size_bytes=64 * 2**20),
    lambda hw: hw.with_mem("HBM", bandwidth=1.2e12),
    lambda hw: hw.with_mem("HBM", cache_line_elems=64),
    lambda hw: hw.with_stencil("mxu", dims=(256, 256, 128)),
    lambda hw: hw.with_stencil("mxu", flops=400e12),
    lambda hw: dataclasses.replace(hw, peak_flops=400e12),
    lambda hw: dataclasses.replace(hw, ici_link_bw=100e9),
    lambda hw: hw.with_params(**{"autotile.mem_cap_frac": 0.6}),
    lambda hw: hw.with_params(**{"fuse.prefer": "prologue"}),
    lambda hw: hw.without_pass("fuse"),
])
def test_fingerprint_changes_on_compilation_relevant_field(mutate):
    hw = get_config("tpu_v5e")
    assert mutate(hw).fingerprint() != hw.fingerprint()


def test_fingerprint_param_key_order_insensitive():
    hw = get_config("cpu_test")
    a = hw.with_params(**{"autotile.mem_cap_elems": 1024, "autotile.search": "divisors"})
    b = hw.with_params(**{"autotile.search": "divisors", "autotile.mem_cap_elems": 1024})
    assert a.fingerprint() == b.fingerprint()


def test_setting_param_to_its_current_value_keeps_fingerprint():
    hw = get_config("tpu_v5e")
    same = hw.with_params(**{"autotile.mem_cap_frac": 0.45,
                             "fuse.prefer": "epilogue"})
    assert same.fingerprint() == hw.fingerprint()


# --------------------------------------------------------------------------
# with_params / structural mutators
# --------------------------------------------------------------------------
def _params_of(hw: HardwareConfig, pass_name: str):
    return dict(hw.passes)[pass_name]


def test_with_params_overrides_only_the_named_pass():
    hw = get_config("tpu_v5e")
    tweaked = hw.with_params(**{"autotile.mem_cap_frac": 0.7})
    assert _params_of(tweaked, "autotile")["mem_cap_frac"] == 0.7
    assert _params_of(tweaked, "fuse") == _params_of(hw, "fuse")
    assert _params_of(tweaked, "schedule") == _params_of(hw, "schedule")
    # the original is untouched (configs are frozen values)
    assert _params_of(hw, "autotile")["mem_cap_frac"] == 0.45


def test_with_params_for_absent_pass_is_a_noop():
    hw = get_config("tpu_v5e").without_pass("fuse")
    assert hw.with_params(**{"fuse.prefer": "prologue"}).fingerprint() == hw.fingerprint()


def test_with_mem_replaces_one_unit_and_rejects_unknown():
    hw = get_config("tpu_v5e")
    grown = hw.with_mem("VMEM", size_bytes=256 * 2**20)
    assert grown.mem("VMEM").size_bytes == 256 * 2**20
    assert grown.mem("HBM") == hw.mem("HBM")
    with pytest.raises(KeyError):
        hw.with_mem("L9", size_bytes=1)
    with pytest.raises(KeyError):
        hw.with_stencil("tensorcore", flops=1.0)


# --------------------------------------------------------------------------
# cache sharing: identical fingerprints share one entry, distinct don't
# --------------------------------------------------------------------------
def test_identical_fingerprints_share_one_cache_entry(tmp_path):
    cache = CompilationCache(disk_dir=tmp_path)
    hw = get_config("cpu_test")
    twin = hw.renamed("cpu_test_sweep_point_7")
    assert twin.fingerprint() == hw.fingerprint()
    _, rec1 = compile_cached(_mm(), hw, cache=cache)
    _, rec2 = compile_cached(_mm(), twin, cache=cache)
    assert not rec1.cache_hit and rec2.cache_hit
    assert rec1.key == rec2.key
    assert len(cache) == 1
    # the hit record is still scorable: tilings/trace travel with the
    # memory entry
    assert rec2.tilings == rec1.tilings
    assert rec2.pass_trace and rec2.n_kernels == rec1.n_kernels


def test_memory_hit_record_scorable_without_disk_tier():
    from repro.core.cost import score_pass_trace

    cache = CompilationCache(use_disk=False)
    hw = get_config("cpu_test")
    _, cold = compile_cached(_mm(), hw, cache=cache)
    _, hot = compile_cached(_mm(), hw, cache=cache)
    assert hot.cache_hit and not hot.disk_hit
    cold_score = score_pass_trace(cold.pass_trace, cold.n_kernels)
    hot_score = score_pass_trace(hot.pass_trace, hot.n_kernels)
    assert cold_score.latency_s > 0
    assert hot_score.latency_s == cold_score.latency_s


def test_distinct_configs_do_not_collide(tmp_path):
    cache = CompilationCache(disk_dir=tmp_path)
    hw = get_config("cpu_test")
    other = hw.with_mem("L2", size_bytes=2 << 20).renamed("cpu_test")  # same NAME
    assert other.fingerprint() != hw.fingerprint()
    _, rec1 = compile_cached(_mm(), hw, cache=cache)
    _, rec2 = compile_cached(_mm(), other, cache=cache)
    assert not rec1.cache_hit and not rec2.cache_hit
    assert rec1.key != rec2.key
    assert len(cache) == 2
