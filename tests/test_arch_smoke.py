"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward/train step on CPU, asserting output shapes and no NaNs; plus
prefill->decode consistency against the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.build import build_model, make_batch

ARCHS = configs.names()


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(name):
    cfg = configs.get(name).scaled()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, "train", 2, 32, seed=1)

    def step(p):
        loss, metrics = m.loss(p, batch, remat=True)
        return loss

    loss, grads = jax.value_and_grad(step)(params)
    assert jnp.isfinite(loss), f"{name}: loss not finite"
    assert float(loss) > 0.5
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), f"{name}: non-finite grad"


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes(name):
    cfg = configs.get(name).scaled()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = make_batch(cfg, "prefill", b, s, seed=2)
    cache = m.init_cache(b, 32)
    logits, cache2 = m.prefill(params, batch, cache)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_then_decode_matches_full_forward(name):
    """Prefill s tokens then decode one more == forward over s+1 tokens.

    MoE capacity-bounded routing legitimately breaks this identity when
    tokens overflow: the per-expert capacity depends on the total token
    count, so prefill(s)+decode(1) and prefill(s+1) drop *different*
    tokens.  The comparison is only well-defined in the no-drop regime,
    so MoE configs run with capacity_factor = n_experts (capacity >= all
    assignments; routing itself is still exercised)."""
    import dataclasses

    cfg = configs.get(name).scaled()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=float(cfg.moe.n_experts)))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    full = make_batch(cfg, "prefill", b, s + 1, seed=3)

    # full forward: loss path exposes logits indirectly; use prefill on s+1
    cache_a = m.init_cache(b, 32)
    logits_full, _ = m.prefill(params, full, cache_a)

    # prefill s, then decode token s
    part = {k: (v[:, :s] if k in ("tokens", "labels") else v) for k, v in full.items()}
    cache_b = m.init_cache(b, 32)
    _, cache_b = m.prefill(params, part, cache_b)
    logits_dec, _ = m.decode_step(params, cache_b, full["tokens"][:, s : s + 1])

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, -1], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_configs_match_assignment():
    """Exact hyperparameters from the assignment table."""
    rows = {
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    for name, (L, d, h, kv, ff, v) in rows.items():
        cfg = configs.get(name)
        assert cfg.n_layers == L and cfg.d_model == d, name
        assert cfg.n_heads == h and cfg.n_kv_heads == kv, name
        assert cfg.d_ff == ff and cfg.vocab == v, name
    assert configs.get("qwen3-moe-30b-a3b").moe.n_experts == 128
    assert configs.get("qwen3-moe-30b-a3b").moe.top_k == 8
    assert configs.get("dbrx-132b").moe.n_experts == 16
    assert configs.get("dbrx-132b").moe.top_k == 4
    assert configs.get("zamba2-2.7b").ssm.d_state == 64
    # padded vocabs divisible by the 16-way model axis
    for name in rows:
        assert configs.get(name).padded_vocab % 16 == 0, name


def test_moe_dispatch_capacity_and_combine():
    from repro.nn.moe import moe_apply, moe_init

    cfg = configs.get("qwen3-moe-30b-a3b").scaled()
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))
    assert float(aux) > 0.5  # balance loss near 1 for random routing


def test_long_context_skip_rules():
    from repro.configs.base import applicable_shapes

    for name in ARCHS:
        cfg = configs.get(name)
        shapes = [s.name for s in applicable_shapes(cfg)]
        if name in ("xlstm-125m", "zamba2-2.7b"):
            assert "long_500k" in shapes, name
        else:
            assert "long_500k" not in shapes, name
