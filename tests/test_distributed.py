"""Multi-device tests (8 fake CPU devices in a subprocess so the main
test process keeps its single-device view).

Each test writes a small driver script, runs it with
XLA_FLAGS=--xla_force_host_platform_device_count=8, and checks output.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_driver(code: str, timeout: int = 420, min_devices: int = 8) -> str:
    """Run a driver script under a forced-8-CPU-device jax.  When the
    platform ignores the forcing (e.g. an already-initialized accelerator
    backend exposes a single device), the test skips with a reason rather
    than failing on mesh construction."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    preamble = (
        "import jax\n"
        f"if jax.device_count() < {min_devices}:\n"
        f"    print('SKIP: only', jax.device_count(), 'device(s) available,'\n"
        f"          ' need {min_devices}')\n"
        "    raise SystemExit(0)\n"
    )
    out = subprocess.run([sys.executable, "-c", preamble + textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"driver failed:\n{out.stdout}\n{out.stderr}"
    if out.stdout.startswith("SKIP:"):
        pytest.skip(out.stdout.strip())
    return out.stdout


def test_dp_tp_train_step_matches_single_device():
    """A sharded train step must produce the same loss as single-device."""
    out = run_driver("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models.build import build_model, make_batch
        from repro.parallel import sharding as shd
        from repro.optim import adamw

        cfg = configs.get('llama3-8b').scaled()
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, 'train', 8, 32)

        def loss_of(p, b):
            return m.loss(p, b, remat=False)[0]

        ref = float(jax.jit(loss_of)(params, batch))

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        sizes = dict(mesh.shape)
        pspecs = shd.param_specs(params, sizes)
        bspecs = shd.batch_specs(batch, ('data',), sizes)
        with mesh:
            to_sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                           is_leaf=lambda x: isinstance(x, P))
            p_sh = jax.device_put(params, to_sh(pspecs))
            b_sh = jax.device_put(batch, to_sh(bspecs))
            got = float(jax.jit(loss_of)(p_sh, b_sh))
        np.testing.assert_allclose(got, ref, rtol=2e-4)
        print('OK', ref, got)
    """)
    assert "OK" in out


def test_zero1_matches_adamw():
    out = run_driver("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim import adamw, zero1

        cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.01)
        params = {'w': jnp.asarray(np.random.RandomState(0).randn(33, 7), jnp.float32),
                  'b': jnp.asarray(np.random.RandomState(1).randn(13), jnp.float32)}
        grads = {'w': jnp.asarray(np.random.RandomState(2).randn(33, 7), jnp.float32),
                 'b': jnp.asarray(np.random.RandomState(3).randn(13), jnp.float32)}

        ref_p, ref_s, _ = adamw.apply_updates(params, grads, adamw.init_state(params), cfg)

        mesh = jax.make_mesh((8,), ('data',))
        z_state = zero1.zero1_init_state(params, 8)
        upd = shard_map(
            partial(zero1.zero1_update, cfg=cfg, axis='data'),
            mesh=mesh,
            in_specs=(P(), P(), {'m': P('data'), 'v': P('data'), 'step': P()}),
            out_specs=(P(), {'m': P('data'), 'v': P('data'), 'step': P()}, P()),
            check_rep=False)
        new_p, new_s, info = jax.jit(upd)(params, grads, z_state)
        for k in params:
            np.testing.assert_allclose(np.asarray(new_p[k]), np.asarray(ref_p[k]), rtol=1e-5, atol=1e-6)
        print('OK zero1')
    """)
    assert "OK zero1" in out


def test_collective_matmul_matches_baseline():
    out = run_driver("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.collective_matmul import (
            ring_allgather_matmul, ring_matmul_reduce_scatter)

        mesh = jax.make_mesh((8,), ('model',))
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(64, 32), jnp.float32)
        w = jnp.asarray(rng.randn(32, 48), jnp.float32)

        # all-gather overlap: x rows sharded, w columns sharded
        ag = shard_map(partial(ring_allgather_matmul, axis='model'), mesh=mesh,
                       in_specs=(P('model', None), P(None, 'model')),
                       out_specs=P(None, 'model'), check_rep=False)
        got = jax.jit(ag)(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-4, atol=1e-4)

        # reduce-scatter overlap: x sharded on K, w rows sharded
        rs = shard_map(partial(ring_matmul_reduce_scatter, axis='model'), mesh=mesh,
                       in_specs=(P(None, 'model'), P('model', None)),
                       out_specs=P(None, 'model'), check_rep=False)
        got2 = jax.jit(rs)(x, w)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(x @ w), rtol=1e-4, atol=1e-4)
        print('OK collective matmul')
    """)
    assert "OK collective matmul" in out


def test_sp_decode_attention_matches_full():
    out = run_driver("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.sp_attention import sp_decode_attention, full_decode_attention_ref

        mesh = jax.make_mesh((8,), ('data',))
        rng = np.random.RandomState(0)
        B, S, H, D = 2, 64, 4, 16
        q = jnp.asarray(rng.randn(B, H, D) * 0.5, jnp.float32)
        k = jnp.asarray(rng.randn(B, S, H, D) * 0.5, jnp.float32)
        v = jnp.asarray(rng.randn(B, S, H, D) * 0.5, jnp.float32)
        valid = jnp.asarray([S, S // 2], jnp.int32)
        scale = 1.0 / np.sqrt(D)

        def sharded(q, k, v, valid):
            s_loc = k.shape[1]
            start = jax.lax.axis_index('data') * s_loc
            vl = jnp.clip(valid - start, 0, s_loc)
            return sp_decode_attention(q, k, v, vl, scale, axis='data')

        fn = shard_map(sharded, mesh=mesh,
                       in_specs=(P(), P(None, 'data'), P(None, 'data'), P()),
                       out_specs=P(), check_rep=False)
        got = jax.jit(fn)(q, k, v, valid)
        want = full_decode_attention_ref(q, k, v, valid, scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
        print('OK sp attention')
    """)
    assert "OK sp attention" in out


def test_pipeline_parallel_matches_sequential():
    out = run_driver("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.pipeline import pipeline_apply, bubble_fraction

        S, M, mb, d = 8, 4, 2, 16   # 8 stages, 4 microbatches
        mesh = jax.make_mesh((8,), ('pod',))
        rng = np.random.RandomState(0)
        ws = jnp.asarray(rng.randn(S, d, d) * 0.3, jnp.float32)
        x = jnp.asarray(rng.randn(M, mb, d), jnp.float32)

        def stage(w, h):
            return jnp.tanh(h @ w)

        def run(ws_shard, micro):
            return pipeline_apply(stage, ws_shard[0], micro, axis='pod')

        fn = shard_map(run, mesh=mesh, in_specs=(P('pod'), P()), out_specs=P(), check_rep=False)
        outs = jax.jit(fn)(ws, x)

        want = x
        for i in range(S):
            want = jnp.tanh(want @ ws[i])
        np.testing.assert_allclose(np.asarray(outs), np.asarray(want), rtol=1e-4, atol=1e-5)
        assert abs(bubble_fraction(8, 4) - 7/11) < 1e-9
        print('OK pipeline')
    """)
    assert "OK pipeline" in out


def test_compressed_psum_error_feedback():
    out = run_driver("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compress import compressed_psum, compression_ratio

        mesh = jax.make_mesh((8,), ('data',))
        rng = np.random.RandomState(0)
        g = jnp.asarray(rng.randn(8, 256) * 0.1, jnp.float32)

        def step(g_shard, res):
            return compressed_psum(g_shard, 'data', res)

        fn = shard_map(step, mesh=mesh, in_specs=(P('data'), P('data')),
                       out_specs=(P('data'), P('data')), check_rep=False)
        res = jnp.zeros_like(g)
        out1, res = jax.jit(fn)(g, res)
        want = jnp.broadcast_to(jnp.sum(g, 0, keepdims=True), g.shape)
        err1 = float(jnp.max(jnp.abs(out1 - want)))
        # error feedback: with the residual applied, a second identical
        # round reduces the bias of the *sum over rounds*
        out2, res2 = jax.jit(fn)(g, res)
        two_round = np.asarray(out1 + out2)
        want2 = np.asarray(2 * want)
        err2 = float(np.max(np.abs(two_round - want2)))
        assert err1 < 0.05, err1
        assert err2 <= 2 * err1 + 1e-6
        assert compression_ratio((1024, 1024)) > 3.5
        print('OK compress', err1, err2)
    """)
    assert "OK compress" in out
