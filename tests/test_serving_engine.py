"""Continuous-batching serving engine: paged-KV correctness vs the dense
reference, slot admission/eviction invariants, compile-cache traffic, disk
warm-start, streaming, and the legacy-API shim."""
import numpy as np
import pytest

from repro import configs
from repro.core import cache as stripe_cache
from repro.models.build import build_model
from repro.serving import (EngineConfig, Request, SamplingParams,
                           ServingEngine, WaveEngine)


def _tiny_cfg():
    return configs.get("llama3-8b").scaled(n_layers=2, d_model=32, n_heads=2,
                                           n_kv_heads=2, d_ff=64, vocab=64,
                                           head_dim=16, vocab_pad_multiple=16)


@pytest.fixture(scope="module")
def model():
    return build_model(_tiny_cfg())


@pytest.fixture(scope="module")
def params(model):
    import jax
    return model.init(jax.random.PRNGKey(0))


def _mk_requests(cfg, plens, new=6, base_uid=0, seed=3):
    r = np.random.RandomState(seed)
    return [Request(uid=base_uid + i,
                    prompt=r.randint(1, cfg.vocab, size=p).astype(np.int32),
                    sampling=SamplingParams(max_new_tokens=new))
            for i, p in enumerate(plens)]


def _engine(model, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("page_size", 8)
    return ServingEngine(model, EngineConfig(**kw))


def _dense_reference(model, params, reqs, max_len=48):
    """Greedy tokens from the dense-cache wave engine, one request at a
    time (batch-1, so no cross-request padding effects)."""
    out = {}
    for r in reqs:
        ref = WaveEngine(model, 1, max_len)
        ref.submit(Request(uid=r.uid, prompt=r.prompt.copy(),
                           sampling=SamplingParams(
                               max_new_tokens=r.sampling.max_new_tokens,
                               eos_id=r.sampling.eos_id)))
        done = ref.run(params, max_steps=4096)
        out[r.uid] = done[0].out_tokens
    return out


# ----------------------------------------------------------- correctness
def test_paged_matches_dense_reference_mixed_lengths(model, params):
    reqs = _mk_requests(model.cfg, [3, 8, 13, 21, 32, 5], new=7)
    want = _dense_reference(model, params, reqs)
    eng = _engine(model, slots=3)
    for r in reqs:
        eng.submit(r)
    done = eng.run(params, max_steps=4096)
    assert sorted(r.uid for r in done) == sorted(r.uid for r in reqs)
    for r in done:
        assert r.out_tokens == want[r.uid], \
            f"uid {r.uid}: paged decode diverged from dense reference"


def test_determinism_across_runs(model, params):
    def run_once():
        eng = _engine(model)
        for r in _mk_requests(model.cfg, [4, 11, 7, 16, 9], new=5):
            eng.submit(r)
        return {r.uid: r.out_tokens for r in eng.run(params, max_steps=4096)}
    a, b = run_once(), run_once()
    assert a == b


# ----------------------------------------------- slot + page accounting
def test_freed_slot_reused_before_queue_growth(model, params):
    """Continuous batching's defining invariant: a finish that frees a
    slot while requests are queued is followed by an admit into that same
    slot at the very next admission phase (same or next step)."""
    eng = _engine(model, slots=2)
    reqs = _mk_requests(model.cfg, [8] * 6, new=5)
    for r in reqs:
        eng.submit(r)
    eng.run(params, max_steps=4096)
    ev = eng.events()
    admits = [e for e in ev if e["event"] == "admit"]
    assert len(admits) == len(reqs)
    for i, e in enumerate(ev):
        if e["event"] != "finish" or e["queue_depth"] == 0:
            continue
        later = [x for x in ev[i + 1:]
                 if x["event"] == "admit" and x["slot"] == e["slot"]]
        assert later, f"slot {e['slot']} freed with queue depth " \
                      f"{e['queue_depth']} but never refilled"
        assert later[0]["step"] <= e["step"] + 1, \
            "freed slot sat idle while the queue was non-empty"


def test_all_pages_released_after_run(model, params):
    eng = _engine(model, slots=2)
    for r in _mk_requests(model.cfg, [5, 17, 9, 30], new=6):
        eng.submit(r)
    eng.run(params, max_steps=4096)
    m = eng.metrics()
    assert m["finished"] == 4
    assert m["free_pages"] == eng.config.pool_pages
    # every slot's page-table row points back at its own garbage page
    for s in range(eng.slots):
        assert (eng._page_table[s] == eng._pool.garbage_page(s)).all()


def test_constrained_pool_blocks_then_proceeds(model, params):
    # pool of 6 pages, each request needs 3 -> at most 2 concurrent even
    # though 4 slots exist; everything still finishes.
    eng = _engine(model, slots=4, max_len=48, page_size=8, pages=6)
    reqs = _mk_requests(model.cfg, [16] * 5, new=8)
    want = _dense_reference(model, params, reqs)
    for r in reqs:
        eng.submit(r)
    done = eng.run(params, max_steps=4096)
    assert len(done) == 5
    concurrent, peak = 0, 0
    for e in eng.events():
        if e["event"] == "admit":
            concurrent += 1
            peak = max(peak, concurrent)
        elif e["event"] == "finish":
            concurrent -= 1
    assert peak <= 2, f"page pool should cap concurrency at 2, saw {peak}"
    for r in done:
        assert r.out_tokens == want[r.uid]


def test_oversized_request_rejected(model):
    eng = _engine(model, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(_mk_requests(model.cfg, [17])[0])


# ------------------------------------------------------ compile pipeline
def test_decode_runs_through_stripe_jit(model, params):
    eng = _engine(model)
    for r in _mk_requests(model.cfg, [6, 12], new=4):
        eng.submit(r)
    eng.run(params, max_steps=4096)
    recs = eng.compile_records()
    for block in ("qkv", "attn_out", "mlp"):
        assert f"decode/{block}" in recs
    mlp = recs["decode/mlp"]
    assert mlp.n_kernels >= 1 and mlp.groups
    # prefill buckets compile through stripe_jit too
    assert any(k.startswith("prefill_L") for k in recs)


def test_bucket_cache_counts_real_traffic(model, params):
    eng = _engine(model)
    # lengths 5 and 6 share the 8-bucket; 12 lands in 16
    for r in _mk_requests(model.cfg, [5, 6, 12, 6], new=3):
        eng.submit(r)
    eng.run(params, max_steps=4096)
    stats = eng.cache_stats()
    assert stats.misses >= 2     # two cold buckets (plus decode/stripe keys)
    assert stats.hits >= 2       # repeat admissions hit the bucket entries
    buckets = [e["bucket"] for e in eng.compile_log() if e["kind"] == "prefill"]
    assert sorted(buckets) == [8, 16]


def test_disk_warm_start(model, params, tmp_path):
    def boot():
        cache = stripe_cache.CompilationCache(
            capacity=64, disk_dir=tmp_path, use_disk=True)
        return ServingEngine(
            model, EngineConfig(slots=2, max_len=48, page_size=8),
            compile_cache=cache)

    first = boot()
    for r in _mk_requests(model.cfg, [5, 12], new=3):
        first.submit(r)
    done_a = first.run(params, max_steps=4096)

    second = boot()
    for r in _mk_requests(model.cfg, [5, 12], new=3):
        second.submit(r)
    done_b = second.run(params, max_steps=4096)
    warm = [e for e in second.events() if e["event"] == "warm_start"]
    assert warm and sorted(warm[0]["buckets"]) == [8, 16]
    warm_prefills = [e for e in second.compile_log()
                     if e["kind"] == "prefill" and e.get("warm_start")]
    assert len(warm_prefills) == 2, "manifest buckets should compile at boot"
    assert {r.uid: r.out_tokens for r in done_a} == \
           {r.uid: r.out_tokens for r in done_b}


# ------------------------------------------------------------------- API
def test_streaming_generate(model, params):
    eng = _engine(model)
    prompts = [p.prompt for p in _mk_requests(model.cfg, [4, 9, 6], new=4)]
    stream = list(eng.generate(prompts, params=params,
                               sampling=SamplingParams(max_new_tokens=4)))
    by_uid = {}
    for uid, tok in stream:
        by_uid.setdefault(uid, []).append(tok)
    assert sorted(by_uid) == [0, 1, 2]
    assert all(len(v) == 4 for v in by_uid.values())
    # the stream is the same tokens run() would return
    eng2 = _engine(model)
    for i, p in enumerate(prompts):
        eng2.submit(Request(uid=i, prompt=p,
                            sampling=SamplingParams(max_new_tokens=4)))
    ref = {r.uid: r.out_tokens for r in eng2.run(params, max_steps=4096)}
    assert by_uid == ref


def test_sjf_admission_prefers_short_jobs(model, params):
    eng = _engine(model, slots=1, admission="sjf")
    long_r, short_r = _mk_requests(model.cfg, [32, 4], new=8)
    eng.submit(long_r)
    eng.submit(short_r)
    done = eng.run(params, max_steps=4096)
    assert [r.uid for r in done] == [short_r.uid, long_r.uid], \
        "sjf should serve the short job first despite arrival order"
    # fcfs keeps arrival order
    eng = _engine(model, slots=1, admission="fcfs")
    a, b = _mk_requests(model.cfg, [32, 4], new=8)
    eng.submit(a)
    eng.submit(b)
    assert [r.uid for r in eng.run(params, max_steps=4096)] == [a.uid, b.uid]


def test_legacy_shim(model, params):
    # positional ints, and flat Request fields, as the old engine took
    eng = ServingEngine(model, 2, 48)
    assert eng.slots == 2 and eng.max_len == 48
    r = Request(uid=0, prompt=np.arange(1, 6, dtype=np.int32),
                max_new_tokens=3, eos_id=-1)
    assert r.sampling.max_new_tokens == 3
    eng.submit(r)
    done = eng.run(params, max_steps=64)
    assert len(done) == 1 and len(done[0].out_tokens) == 3


def test_temperature_not_implemented():
    with pytest.raises(NotImplementedError):
        SamplingParams(temperature=0.7).validate()


def test_non_dense_family_rejected(params):
    cfg = _tiny_cfg()
    cfg = cfg.scaled(family="moe")
    model = build_model(cfg)
    with pytest.raises(ValueError, match="WaveEngine"):
        ServingEngine(model, EngineConfig(slots=2, max_len=32))


# ----------------------------------------------------- resilience contract
def test_submit_accepts_and_statuses_default_ok(model, params):
    # without max_queue/ttl the resilience layer is invisible: submit()
    # returns True, nothing sheds, every request finishes status "ok"
    eng = _engine(model)
    for r in _mk_requests(model.cfg, [4, 9, 6]):
        assert eng.submit(r) is True
    done = eng.run(params, max_steps=4096)
    assert all(r.status == "ok" and r.error == "" and r.retries == 0
               for r in done)
    assert eng.shed() == []
    m = eng.metrics()
    assert m["shed"] == 0 and m["retries"] == 0 and m["quarantined"] == 0
    assert m["finished_by_status"] == {"ok": 3}


def test_bounded_queue_sheds_synchronously(model, params):
    eng = _engine(model, slots=1, max_queue=2)
    reqs = _mk_requests(model.cfg, [4, 5, 6, 7])
    results = [eng.submit(r) for r in reqs]
    # prep drains fast, so at least the request submitted against a full
    # queue is shed; shed requests never reach the engine
    assert results[0] is True
    assert not all(results), "queue of 2 must shed some of 4 rapid submits"
    done = eng.run(params, max_steps=4096)
    shed_uids = {r.uid for r in eng.shed()}
    assert {r.uid for r in done}.isdisjoint(shed_uids)
    assert {r.uid for r in done} | shed_uids == {r.uid for r in reqs}
    for r in eng.shed():
        assert r.status == "shed" and r.done and r.out_tokens == []
