"""Parallel autotune: the worker-pool search must pick the identical
tiling to the serial path (deterministic tie-breaking), and complete an
exhaustive search space in reasonable time."""
import time

from repro.core import single_op_program
from repro.core.hwconfig import PAPER_FIG4, TPU_V5E
from repro.core.passes.autotile import choose_tiling


def _fig4_conv_block():
    prog = single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "int8"), "F": ((3, 3, 8, 16), "int8"),
         "O": ((12, 16, 16), "int32")},
        out="O",
    )
    return prog.entry.stmts[0]


def test_parallel_matches_serial_on_fig4_conv():
    blk = _fig4_conv_block()
    params = dict(PAPER_FIG4.passes[0][1])
    tiles_s, cost_s = choose_tiling(blk, PAPER_FIG4, params)
    tiles_p, cost_p = choose_tiling(
        blk, PAPER_FIG4, dict(params, workers=2, parallel_min_combos=1))
    assert tiles_s == tiles_p
    assert cost_s.cost == cost_p.cost
    # the paper's Fig. 4 answer: a 3x4 output tile
    assert (tiles_s["x"], tiles_s["y"]) == (3, 4)


def test_parallel_matches_serial_on_roofline_pow2():
    prog = single_op_program(
        "O[i, j] += X[i, c] * W[c, j]",
        {"X": ((2048, 1024), "bfloat16"), "W": ((1024, 2048), "bfloat16"),
         "O": ((2048, 2048), "bfloat16")},
        out="O",
    )
    params = {"cost": "roofline", "search": "pow2", "mem_cap_frac": 0.45,
              "count_untiled": True}
    tiles_s, cost_s = choose_tiling(prog.entry.stmts[0], TPU_V5E, params)
    tiles_p, cost_p = choose_tiling(
        prog.entry.stmts[0], TPU_V5E,
        dict(params, workers=2, parallel_min_combos=1))
    assert tiles_s == tiles_p and cost_s.cost == cost_p.cost


def test_parallel_exhaustive_speed_smoke():
    prog = single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((32, 32), "float32"), "B": ((32, 32), "float32"),
         "O": ((32, 32), "float32")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    params = {"cost": "cache_lines", "search": "exhaustive", "mem_cap_elems": 2048}
    t0 = time.perf_counter()
    tiles_s, cost_s = choose_tiling(blk, PAPER_FIG4, params)
    t_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    tiles_p, cost_p = choose_tiling(blk, PAPER_FIG4, dict(params, workers=2))
    t_parallel = time.perf_counter() - t0
    assert tiles_s == tiles_p and cost_s.cost == cost_p.cost
    # smoke, not a strict benchmark: the pool must not be pathologically
    # slower than the serial loop (generous bound for 2-core CI runners)
    assert t_parallel < max(t_serial * 3, 5.0), (t_serial, t_parallel)


def test_workers_one_is_serial_path():
    blk = _fig4_conv_block()
    params = dict(PAPER_FIG4.passes[0][1])
    tiles_a, _ = choose_tiling(blk, PAPER_FIG4, dict(params, workers=1))
    tiles_b, _ = choose_tiling(blk, PAPER_FIG4, params)
    assert tiles_a == tiles_b
