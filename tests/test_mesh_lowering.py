"""Multi-device lowering: ``stripe_jit(..., mesh=)`` through shard_map.

The ``distributed``-marked tests run **in process** on the 8 emulated
host devices conftest forces before jax initializes; the plan-level and
explore tests touch no devices at all.  Every device test closes the
predicted-vs-emitted loop: the collectives the shard plan priced are the
collective primitives the jaxpr actually contains
(``count_collectives`` == ``expected_primitive_counts``).
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import mesh_lower
from repro.core.cost import collective_seconds, score_pass_trace
from repro.core.driver import compile_cached, stripe_jit
from repro.core.frontend import TileProgram
from repro.core.hwconfig import CPU_TEST, TPU_V5E
from repro.core.shardplan import UnsupportedMesh, plan_program

distributed = pytest.mark.distributed


# --------------------------------------------------------------------------
# workloads
# --------------------------------------------------------------------------
def ffn(m=256, k=64, n=64):
    tp = TileProgram("ffn")
    tp.input("X", (m, k), "float32")
    tp.input("W", (k, n), "float32")
    tp.input("B", (n,), "float32")
    tp.output("O", (m, n), "float32")
    tp.temp("T", (m, n), "float32")
    tp.temp("U", (m, n), "float32")
    tp.op("T[i, j] += X[i, c] * W[c, j]", name="mm")
    tp.op("U[i, j] = T[i, j] + B[j]", name="bias")
    tp.op("O[i, j] = gelu(U[i, j])", name="act")
    return tp.build()


def matmul(m, k, n):
    tp = TileProgram("mm")
    tp.input("X", (m, k), "float32")
    tp.input("W", (k, n), "float32")
    tp.output("O", (m, n), "float32")
    tp.op("O[i, j] += X[i, c] * W[c, j]", name="mm")
    return tp.build()


def halo_conv(x=32, y=15, c=5, k=7):
    tp = TileProgram("conv")
    tp.input("I", (x, y, c), "float32")
    tp.input("F", (3, 3, c, k), "float32")
    tp.output("O", (x, y, k), "float32")
    tp.op("O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
          name="conv")
    return tp.build()


def mlp2(m=12, c=24, h=4096, f=64):
    """Two chained matmuls whose only divisible dims are the hidden
    contraction ``h`` and the ring-eligible ``f`` — forces a
    reduction split on mm2 (psum or ring, by cost)."""
    tp = TileProgram("mlp2")
    tp.input("X", (m, c), "float32")
    tp.input("W1", (c, h), "float32")
    tp.input("W2", (h, f), "float32")
    tp.output("O", (m, f), "float32")
    tp.temp("H", (m, h), "float32")
    tp.op("H[i, h] += X[i, c] * W1[c, h]", name="mm1")
    tp.op("O[i, f] += H[i, h] * W2[h, f]", name="mm2")
    return tp.build()


def _arrays(prog, seed=0):
    rng = np.random.default_rng(seed)
    return {name: rng.normal(size=prog.buffers[name].shape).astype("float32")
            for name in prog.inputs}


def _assert_predicted_collectives(compiled, arrays):
    """The plan's predicted collective primitives must equal the emitted
    jaxpr's, and the recorded bytes must equal the interconnect model's
    per-device moved bytes for those collectives."""
    plan_counts = {}
    for c in compiled.record.mesh["collectives"]:
        # record -> primitive name (ring = ppermute loop + gather)
        if c["collective"] == "ring_matmul":
            for p in ("ppermute", "all_gather"):
                plan_counts[p] = plan_counts.get(p, 0) + 1
        elif c["collective"] == "halo":
            pass  # counted via lo/hi below
        else:
            p = c["collective"]
            plan_counts[p] = plan_counts.get(p, 0) + 1
    got = mesh_lower.count_collectives(compiled._fn, arrays)
    for prim, n in plan_counts.items():
        assert got.get(prim, 0) >= n, (prim, plan_counts, got)
    total = sum(c["bytes"] for c in compiled.record.mesh["collectives"])
    assert total == compiled.record.mesh["collective_bytes"]
    assert total > 0


# --------------------------------------------------------------------------
# device tests (8 emulated host devices, in process)
# --------------------------------------------------------------------------
@distributed
def test_ffn_mesh8_pallas_matches_single_device():
    """The acceptance workload: matmul -> bias -> gelu compiled through
    shard_map on 8 devices with per-shard Pallas (interpret) kernels,
    output-split, exact against the single-device lowering."""
    prog = ffn()
    arrays = _arrays(prog)
    ref = stripe_jit(ffn(), CPU_TEST, backend="jnp")(arrays)
    c = stripe_jit(ffn(), CPU_TEST, backend="pallas", interpret=True, mesh=8)
    out = c(arrays)
    np.testing.assert_allclose(out["O"], ref["O"], rtol=1e-5, atol=1e-5)

    rec = c.record
    assert rec.backend == "pallas"          # per-shard kernels are Pallas
    assert rec.mesh["n_devices"] == 8
    assert rec.mesh["shape"] == [8]
    assert rec.mesh["splits"]               # at least the seed block split
    assert rec.mesh["segments"], "segments carry their own compile records"
    for seg in rec.mesh["segments"]:
        assert seg["backend"] == "pallas"
    # predicted == emitted
    counts = mesh_lower.count_collectives(c._fn, arrays)
    assert counts == mesh_lower.expected_primitive_counts_from_record(rec.mesh)
    _assert_predicted_collectives(c, arrays)
    # the sharded-output gather moves (n-1)/n of the output per device
    n = 8
    out_bytes = 256 * 64 * 4
    assert rec.mesh["collective_bytes"] == pytest.approx(
        collective_seconds("all_gather", out_bytes, n, 1.0))


@distributed
def test_reduction_split_psum_tolerance_exact():
    """A matmul whose only divisible index is the contraction: the plan
    must emit full-shape partials + one psum, tolerance-exact in f32."""
    prog = matmul(12, 64, 20)
    arrays = _arrays(prog)
    ref = stripe_jit(matmul(12, 64, 20), CPU_TEST, backend="jnp")(arrays)
    c = stripe_jit(matmul(12, 64, 20), CPU_TEST, backend="jnp", mesh=8)
    out = c(arrays)
    np.testing.assert_allclose(out["O"], ref["O"], rtol=1e-5, atol=1e-5)
    counts = mesh_lower.count_collectives(c._fn, arrays)
    assert counts.get("psum") == 1
    ops = [col["collective"] for col in c.record.mesh["collectives"]]
    assert ops == ["psum"]
    # psum of the (12, 20) f32 partials: 2(n-1)/n of the payload moves
    assert c.record.mesh["collective_bytes"] == pytest.approx(
        collective_seconds("psum", 12 * 20 * 4, 8, 1.0))


@distributed
def test_halo_conv_bit_exact():
    """A 3x3 conv split on the spatial x dim: boundary slabs move by
    ppermute (zero-filled at the ends — exactly the dropped frontend
    boundary constraints), bit-exact against single-device."""
    prog = halo_conv()
    arrays = _arrays(prog)
    ref = stripe_jit(halo_conv(), CPU_TEST, backend="jnp")(arrays)
    c = stripe_jit(halo_conv(), CPU_TEST, backend="jnp", mesh=8)
    out = c(arrays)
    np.testing.assert_array_equal(np.asarray(out["O"]),
                                  np.asarray(ref["O"]))
    counts = mesh_lower.count_collectives(c._fn, arrays)
    assert counts.get("ppermute") == 2      # lo + hi margins
    assert counts.get("all_gather") == 1    # sharded output
    ops = sorted(col["collective"] for col in c.record.mesh["collectives"])
    assert ops == ["all_gather", "halo"]


@distributed
def test_ring_overlap_chosen_by_cost():
    """The gather/compute-interleaved ring matmul is the schedule's
    overlap primitive — chosen by the interconnect model, not by hand:
    slow links + slow compute pick the ring, stock links pick psum.
    Both are numerically correct."""
    prog = mlp2()
    arrays = _arrays(prog)
    ref = stripe_jit(mlp2(), CPU_TEST, backend="jnp")(arrays)

    slow = dataclasses.replace(TPU_V5E, ici_link_bw=1e7, peak_flops=1e8)
    c_ring = stripe_jit(mlp2(), slow, backend="jnp", mesh=8)
    assert c_ring.record.mesh["overlapped"], "expected ring overlap"
    ops = [col["collective"] for col in c_ring.record.mesh["collectives"]]
    assert "ring_matmul" in ops
    out = c_ring(arrays)
    np.testing.assert_allclose(out["O"], ref["O"], rtol=1e-4, atol=1e-4)
    counts = mesh_lower.count_collectives(c_ring._fn, arrays)
    assert counts == mesh_lower.expected_primitive_counts_from_record(
        c_ring.record.mesh)

    c_psum = stripe_jit(mlp2(), TPU_V5E, backend="jnp", mesh=8)
    ops = [col["collective"] for col in c_psum.record.mesh["collectives"]]
    assert "psum" in ops and "ring_matmul" not in ops
    assert not c_psum.record.mesh["overlapped"]
    out = c_psum(arrays)
    np.testing.assert_allclose(out["O"], ref["O"], rtol=1e-4, atol=1e-4)


@distributed
def test_mesh_fallback_indivisible():
    """No divisible index -> single-device compile, reason recorded."""
    prog = matmul(13, 7, 5)
    arrays = _arrays(prog)
    c = stripe_jit(matmul(13, 7, 5), CPU_TEST, backend="jnp", mesh=8)
    assert "fallback" in c.record.mesh
    assert "divisible" in c.record.mesh["fallback"]
    ref = stripe_jit(matmul(13, 7, 5), CPU_TEST, backend="jnp")(arrays)
    np.testing.assert_allclose(c(arrays)["O"], ref["O"], rtol=1e-6)


@distributed
def test_mesh_shape_tuple_and_api_facade():
    """api.jit(mesh=(2, 4)) and the api.Mesh re-export both work; the
    2-D model shape flattens to one execution axis over 8 devices."""
    import jax

    from repro import api

    assert api.Mesh is jax.sharding.Mesh
    prog = ffn()
    arrays = _arrays(prog)
    ref = api.jit(ffn(), CPU_TEST, backend="jnp")(arrays)
    c = api.jit(ffn(), CPU_TEST, backend="jnp", mesh=(2, 4))
    assert c.record.mesh["shape"] == [2, 4]
    assert c.record.mesh["n_devices"] == 8
    np.testing.assert_allclose(c(arrays)["O"], ref["O"], rtol=1e-5, atol=1e-5)

    # an explicit jax Mesh is accepted as-is
    jmesh = jax.sharding.Mesh(np.array(jax.devices()[:8]), ("dev",))
    c2 = api.jit(ffn(), CPU_TEST, backend="jnp", mesh=jmesh)
    np.testing.assert_allclose(c2(arrays)["O"], ref["O"], rtol=1e-5, atol=1e-5)


@distributed
def test_mesh_compile_memory_cache_hit():
    prog = ffn()
    arrays = _arrays(prog)
    c1 = stripe_jit(ffn(), CPU_TEST, backend="jnp", mesh=8)
    c2 = stripe_jit(ffn(), CPU_TEST, backend="jnp", mesh=8)
    assert not c1.record.cache_hit
    assert c2.record.cache_hit
    assert c2.record.mesh["collective_bytes"] == \
        c1.record.mesh["collective_bytes"]
    np.testing.assert_allclose(c2(arrays)["O"], c1(arrays)["O"])


@distributed
def test_axis_size_inside_and_outside_shard_map():
    """compat.axis_size resolves inside a shard_map trace AND at trace
    level under an ambient `with mesh:` context (the satellite fix)."""
    from functools import partial

    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.parallel import compat

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:8]), ("data",))

    def body(x):
        return x * compat.axis_size("data")

    fn = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                   check_rep=False)
    out = jax.jit(fn)(jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(out), 8.0)

    # outside any trace: the ambient mesh context supplies the size
    with mesh:
        assert compat.axis_size("data") == 8
    assert compat.axis_size("data", mesh=mesh) == 8
    with pytest.raises(NameError):
        compat.axis_size("nonexistent_axis")


# --------------------------------------------------------------------------
# property tests: partitioned == single-device over drawn shapes
# --------------------------------------------------------------------------
@distributed
@settings(max_examples=6, deadline=None)
@given(m=st.sampled_from([8, 16, 24]), k=st.sampled_from([8, 16]),
       n=st.sampled_from([8, 16]))
def test_property_matmul_output_split(m, k, n):
    prog = matmul(m, k, n)
    arrays = _arrays(prog, seed=m * 100 + k * 10 + n)
    ref = stripe_jit(matmul(m, k, n), CPU_TEST, backend="jnp")(arrays)
    c = stripe_jit(matmul(m, k, n), CPU_TEST, backend="jnp", mesh=8)
    np.testing.assert_allclose(c(arrays)["O"], ref["O"],
                               rtol=1e-5, atol=1e-5)


@distributed
@settings(max_examples=4, deadline=None)
@given(m=st.sampled_from([8, 32]), k=st.sampled_from([16, 48]))
def test_property_ffn_matches(m, k):
    prog = ffn(m, k, 16)
    arrays = _arrays(prog, seed=m + k)
    ref = stripe_jit(ffn(m, k, 16), CPU_TEST, backend="jnp")(arrays)
    c = stripe_jit(ffn(m, k, 16), CPU_TEST, backend="jnp", mesh=8)
    np.testing.assert_allclose(c(arrays)["O"], ref["O"],
                               rtol=1e-5, atol=1e-5)


@distributed
@settings(max_examples=4, deadline=None)
@given(x=st.sampled_from([16, 32]), y=st.sampled_from([9, 15]),
       c=st.sampled_from([3, 5]))
def test_property_halo_conv_bit_exact(x, y, c):
    prog = halo_conv(x, y, c, 4)
    arrays = _arrays(prog, seed=x + y + c)
    ref = stripe_jit(halo_conv(x, y, c, 4), CPU_TEST, backend="jnp")(arrays)
    cc = stripe_jit(halo_conv(x, y, c, 4), CPU_TEST, backend="jnp", mesh=8)
    np.testing.assert_array_equal(np.asarray(cc(arrays)["O"]),
                                  np.asarray(ref["O"]))


# --------------------------------------------------------------------------
# plan-level tests (no devices)
# --------------------------------------------------------------------------
def test_plan_collective_bytes_model():
    """The plan's recorded bytes are the interconnect model's per-device
    moved bytes: all_gather (n-1)/n, psum 2(n-1)/n, halo = margin."""
    n = 8
    plan = plan_program(ffn(), n, TPU_V5E, (n,))
    ag = [c for c in plan.collectives if c.op == "all_gather"]
    assert len(ag) == 1
    assert ag[0].nbytes == pytest.approx(
        collective_seconds("all_gather", 256 * 64 * 4, n, 1.0))

    plan2 = plan_program(matmul(12, 64, 20), n, TPU_V5E, (n,))
    ps = [c for c in plan2.collectives if c.op == "psum"]
    assert len(ps) == 1
    assert ps[0].nbytes == pytest.approx(
        collective_seconds("psum", 12 * 20 * 4, n, 1.0))

    plan3 = plan_program(halo_conv(), n, TPU_V5E, (n,))
    halos = [c for c in plan3.collectives if c.op == "halo"]
    assert halos and all(h.nbytes > 0 for h in halos)


def test_plan_unsupported_raises():
    with pytest.raises(UnsupportedMesh):
        plan_program(matmul(13, 7, 5), 8, TPU_V5E, (8,))


def test_mesh_link_multiplier_lowers_comm_time():
    """A 2-D mesh shape multiplies the link bandwidth (more links per
    device) — same bytes, less exposed time."""
    flat = plan_program(ffn(), 8, TPU_V5E, (8,))
    grid = plan_program(ffn(), 8, TPU_V5E, (2, 4))
    assert grid.collective_bytes() == flat.collective_bytes()
    assert grid.comm_s < flat.comm_s


def test_partition_pass_mesh_annotation():
    """hw.with_mesh() activates the partition pass's annotation mode:
    split tags on the optimized blocks, collective records in the trace,
    comm terms in the score."""
    hw = TPU_V5E.with_mesh((8,))
    opt, rec = compile_cached(ffn(), hw)
    score = score_pass_trace(rec.pass_trace, rec.n_kernels)
    assert score.comm_bytes > 0
    assert score.n_collectives >= 1
    assert score.comm_s > 0
    tagged = [b for b in opt.entry.stmts
              if hasattr(b, "tags") and "partitioned" in b.tags]
    assert tagged, "split decision must be visible on the optimized blocks"

    base_score = score_pass_trace(
        compile_cached(ffn(), TPU_V5E)[1].pass_trace)
    assert base_score.comm_bytes == 0


def test_partition_pass_mesh_fallback_reports():
    hw = TPU_V5E.with_mesh((8,))
    opt, rec = compile_cached(matmul(13, 7, 5), hw)
    part = [e for e in rec.pass_trace if e[0] == "partition"]
    assert part and len(part[0]) > 2
    assert any("fallback" in r for r in part[0][2] if isinstance(r, dict))
    score = score_pass_trace(rec.pass_trace, rec.n_kernels)
    assert score.comm_bytes == 0


def test_with_mesh_normalizes_trivial():
    assert TPU_V5E.with_mesh((1,)).fingerprint() == TPU_V5E.fingerprint()
    assert TPU_V5E.with_mesh((1, 1)).mesh == ()
    hw = TPU_V5E.with_mesh((2, 4))
    assert hw.mesh == (2, 4)
    assert hw.mesh_devices() == 8
    assert hw.passes[0][0] == "partition"
    assert hw.fingerprint() != TPU_V5E.fingerprint()
    # idempotent: no duplicate partition pass
    again = hw.with_mesh((2, 4))
    assert [n for n, _ in again.passes].count("partition") == 1


def test_mesh_sweep_space_pareto():
    """The explore integration end-to-end without devices: the mesh axis
    sweeps, points score with comm_bytes, and the Pareto front uses the
    communication axis."""
    from repro.explore.report import PARETO_AXES, build_report, to_markdown
    from repro.explore.runner import run_sweep
    from repro.explore.space import get_space

    assert "comm_bytes" in PARETO_AXES
    space = get_space("mesh-sweep")
    assert any(a.path == "mesh" for a in space.axes)
    sweep = run_sweep(space, "default", budget=5, strategy="grid",
                      measure_top_k=0)
    report = build_report(sweep)
    meshed = [p for p in report["points"]
              if p["point"].get("mesh", (1,)) not in ((1,), [1])
              and not p["error"] and p["dedup_of"] is None]
    assert meshed, "sweep must score at least one meshed point"
    assert all(p["comm_bytes"] > 0 for p in meshed)
    # baseline (and the stock point) spend no communication
    assert report["baseline"]["comm_bytes"] == 0
    md = to_markdown(sweep)
    assert "comm (B)" in md


def test_space_mesh_axis_formatting():
    from repro.explore.space import Axis, SearchSpace

    space = SearchSpace(name="t", base="tpu_v5e",
                        axes=(Axis("mesh", ((1,), (2, 4)), default=(1,)),))
    assert space.point_name({"mesh": (2, 4)}).endswith("mesh=2x4")
    cfg = space.apply({"mesh": (2, 4)})
    assert cfg.mesh == (2, 4)
    # the stock point IS the base config (fingerprint dedupe)
    assert space.apply({"mesh": (1,)}).fingerprint() == \
        space.base_config().fingerprint()


def test_explore_help_lists_mesh_axes():
    from repro.explore.__main__ import _space_epilog

    epilog = _space_epilog()
    assert "mesh-sweep" in epilog
    assert "2x4" in epilog
