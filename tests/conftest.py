"""Test configuration.

When the real ``hypothesis`` package is unavailable (it is pinned in the
``[dev]`` extra and installed in CI, but some sandboxes cannot install
packages), install a minimal deterministic fallback into ``sys.modules``
so the property tests still collect and run.  The fallback implements
only the slice of the API these tests use — ``@given``/``@settings`` and
the ``integers``/``sampled_from``/``dictionaries`` strategies — drawing a
fixed number of pseudo-random examples from a per-test seeded RNG (no
shrinking, no database).
"""
from __future__ import annotations

import functools
import os
import random
import sys
import types
import zlib

import pytest

# Multi-device tests run *in process* on emulated host devices: force the
# device count before jax first initializes (a no-op if something already
# imported jax — then @pytest.mark.distributed tests skip instead).  An
# explicit user-provided forcing flag is left alone.
_FORCE_FLAG = "--xla_force_host_platform_device_count"
if "jax" not in sys.modules and _FORCE_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE_FLAG}=8").strip()


def pytest_collection_modifyitems(config, items):
    if not any(item.get_closest_marker("distributed") for item in items):
        return
    import jax

    n = jax.device_count()
    if n >= 8:
        return
    skip = pytest.mark.skip(
        reason=f"needs 8 jax devices, have {n} (XLA_FLAGS forcing was "
               "preempted by an earlier jax init)")
    for item in items:
        if item.get_closest_marker("distributed"):
            item.add_marker(skip)


@pytest.fixture(autouse=True)
def _isolated_stripe_cache(tmp_path, monkeypatch):
    """Keep the compilation cache out of the user's real ~/.cache: every
    test gets a private disk dir and a fresh process-default cache, so no
    test is ever served a stale entry written by older code."""
    from repro.core import cache as stripe_cache

    monkeypatch.setenv(stripe_cache.ENV_CACHE_DIR, str(tmp_path / "stripe-cache"))
    monkeypatch.delenv(stripe_cache.ENV_CACHE_DISABLE, raising=False)
    stripe_cache.set_default_cache(None)
    yield
    stripe_cache.set_default_cache(None)

try:
    import hypothesis  # noqa: F401  (the real one wins when present)
except ModuleNotFoundError:
    class _Strategy:
        def example(self, rng: random.Random):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value, max_value):
            self.min_value, self.max_value = min_value, max_value

        def example(self, rng):
            return rng.randint(self.min_value, self.max_value)

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def example(self, rng):
            return rng.choice(self.elements)

    class _Dictionaries(_Strategy):
        def __init__(self, keys, values, dict_class=dict, min_size=0, max_size=None):
            self.keys, self.values = keys, values
            self.dict_class = dict_class
            self.min_size = min_size
            self.max_size = min_size + 4 if max_size is None else max_size

        def example(self, rng):
            size = rng.randint(self.min_size, self.max_size)
            out = self.dict_class()
            for _ in range(100):
                if len(out) >= size:
                    break
                k = self.keys.example(rng)
                if k not in out:
                    out[k] = self.values.example(rng)
            return out

    def _given(*strats, **kwstrats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_fallback_max_examples", 20)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = tuple(s.example(rng) for s in strats)
                    kdrawn = {k: s.example(rng) for k, s in kwstrats.items()}
                    fn(*args, *drawn, **kwargs, **kdrawn)

            # pytest must see a zero-arg signature (drawn args are not
            # fixtures), so drop the wraps-added signature forwarding
            del wrapper.__wrapped__
            # mimic real hypothesis: plugins (e.g. anyio) reach for
            # fn.hypothesis.inner_test
            wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
            return wrapper

        return deco

    def _settings(max_examples=100, deadline=None, **_ignored):
        def deco(fn):
            # functools.wraps copies __dict__, so this survives either
            # decorator order relative to @given
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = lambda min_value, max_value: _Integers(min_value, max_value)
    st_mod.sampled_from = lambda elements: _SampledFrom(elements)
    st_mod.dictionaries = (
        lambda keys, values, dict_class=dict, min_size=0, max_size=None:
        _Dictionaries(keys, values, dict_class, min_size, max_size)
    )

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = _given
    hyp_mod.settings = _settings
    hyp_mod.strategies = st_mod
    hyp_mod.__fallback__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
