"""Fault-injection framework: rule scheduling, determinism, plan
stacking, cross-thread visibility; plus the injection sites wired into
the compilation cache, the stripe_jit driver (compile quarantine), and
the training loop (FaultInjector compat shim)."""
import threading
import time

import numpy as np
import pytest

from repro.core import CompilationCache, single_op_program, stripe_jit
from repro.core.cache import QuarantineStore, content_key
from repro.core.hwconfig import CPU_TEST
from repro.reliability import faults


# ------------------------------------------------------------- framework
def test_fail_nth_fires_exactly_once_on_nth_hit():
    with faults.inject(faults.fail_nth("train.step", 3)) as plan:
        fired_at = []
        for step in range(6):
            try:
                faults.check("train.step", step=step)
            except faults.InjectedFault as e:
                fired_at.append(step)
                assert e.site == "train.step"
                assert e.ctx == {"step": step}
    assert fired_at == [2]  # nth is 1-based over hits
    assert plan.fired_counts() == {"train.step": 1}
    assert plan.fired()[0]["hit"] == 3


def test_fail_every_with_times_bound():
    with faults.inject(faults.fail_every("train.step", 2, times=2)) as plan:
        hits = [faults.fires("train.step", step=i) for i in range(10)]
    assert hits == [False, True, False, True] + [False] * 6
    assert plan.fired_counts()["train.step"] == 2


def test_fail_prob_is_deterministic_under_seed():
    def run(seed):
        with faults.inject(faults.fail_prob("serve.decode_step", 0.3,
                                            seed=seed, times=None)):
            return [faults.fires("serve.decode_step", step=i)
                    for i in range(200)]
    a, b = run(7), run(7)
    assert a == b, "same seed must fire identically"
    assert 20 < sum(a) < 120, "p=0.3 over 200 hits should fire a sane count"
    assert run(8) != a, "different seed should differ"


def test_when_predicate_and_payload():
    rule = faults.fail_when("serve.decode_step",
                            lambda ctx: ctx["step"] >= 5,
                            payload={"slots": [1]})
    with faults.inject(rule):
        assert not faults.fires("serve.decode_step", step=4)
        with pytest.raises(faults.InjectedFault) as ei:
            faults.check("serve.decode_step", step=5)
    assert ei.value.payload == {"slots": [1]}
    assert isinstance(ei.value, RuntimeError)  # legacy handlers keep working


def test_unknown_site_rejected():
    with pytest.raises(KeyError):
        faults.fail_nth("serve.nonexistent", 1)
    with faults.inject(faults.fail_nth("train.step", 1)):
        with pytest.raises(KeyError):
            faults.check("not.a.site")
    # without active plans check() is a no-op even for unknown sites
    faults.check("not.a.site")


def test_wildcard_site_pattern_and_plan_stacking():
    outer = faults.FaultPlan([faults.fail_every("serve.*", 1, times=None)])
    with faults.inject(outer):
        assert faults.fires("serve.prep", uid=1)
        with faults.inject(faults.fail_nth("train.step", 1)) as inner:
            assert faults.fires("train.step", step=0)
            assert faults.fires("serve.decode_step", step=0)  # outer still active
        assert inner.fired_counts() == {"train.step": 1}
    assert not faults.fires("serve.prep", uid=2), "plan must uninstall on exit"
    assert outer.fired_counts()["serve.prep"] == 1


def test_plans_visible_across_threads():
    seen = []

    def worker():
        seen.append(faults.fires("serve.prep", uid=0))

    with faults.inject(faults.fail_nth("serve.prep", 1)):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen == [True], "prep-thread-style workers must observe the plan"


# ------------------------------------------------------- quarantine store
def test_quarantine_backoff_doubles_and_expiry_permits_retry():
    q = QuarantineStore(base_backoff_s=0.05, max_backoff_s=1.0)
    e1 = q.record_failure("k", "boom")
    assert e1.backoff_s == pytest.approx(0.05)
    assert q.active("k"), "embargo must hold right after the failure"
    time.sleep(0.06)
    assert not q.active("k"), "expiry must permit a retry"
    assert q.get("k").expired
    assert q.stats.quarantine_expiries == 1
    e2 = q.record_failure("k", "boom again")  # failed retry doubles backoff
    assert e2.backoff_s == pytest.approx(0.1)
    assert e2.fail_count == 2
    assert q.clear("k")
    assert q.get("k") is None
    assert q.stats.quarantine_clears == 1


# ------------------------------------------------------------ cache sites
def test_disk_read_fault_degrades_to_miss(tmp_path):
    c = CompilationCache(disk_dir=tmp_path)
    c.put_disk("k", {"v": 1})
    with faults.inject(faults.fail_nth("cache.disk_read", 1)):
        assert c.get_disk("k") is None, "injected read error must read as a miss"
    assert c.stats.disk_errors == 1
    assert c.get_disk("k") == {"v": 1}, "the entry itself must be intact"


def test_disk_write_fault_loses_entry_without_crashing(tmp_path):
    c = CompilationCache(disk_dir=tmp_path)
    with faults.inject(faults.fail_nth("cache.disk_write", 1)):
        c.put_disk("k", {"v": 1})
    assert c.get_disk("k") is None
    assert c.stats.disk_errors == 1
    c.put_disk("k", {"v": 2})
    assert c.get_disk("k") == {"v": 2}


# -------------------------------------------------- driver quarantine
def _mm_kwargs():
    return dict(tensors={"A": ((32, 16), "float32"), "B": ((16, 24), "float32"),
                         "O": ((32, 24), "float32")}, out="O")


def test_stripe_jit_compile_crash_quarantines_and_recovers(tmp_path):
    cache = CompilationCache(disk_dir=tmp_path)
    cache.quarantine.base_backoff_s = 60.0  # hold the embargo for the test
    rng = np.random.RandomState(0)
    arrays = {"A": rng.randn(32, 16).astype(np.float32),
              "B": rng.randn(16, 24).astype(np.float32)}
    want = arrays["A"] @ arrays["B"]

    with faults.inject(faults.fail_nth("compile.stripe_jit", 1)):
        cp = stripe_jit("O[i, j] += A[i, c] * B[c, j]", CPU_TEST, "pallas",
                        cache=cache, **_mm_kwargs())
    # the crash is absorbed: same call, same result, jnp fallback + quarantine
    assert cp.record.quarantined
    assert "compile crashed" in cp.record.fallback_reason
    np.testing.assert_allclose(np.asarray(cp(arrays)["O"]), want,
                               rtol=1e-4, atol=1e-5)
    assert cache.stats.quarantined == 1

    # while embargoed, the cached entry keeps serving the fallback
    cp2 = stripe_jit("O[i, j] += A[i, c] * B[c, j]", CPU_TEST, "pallas",
                     cache=cache, **_mm_kwargs())
    assert cp2.record.quarantined
    assert cache.stats.quarantine_hits >= 1

    # after the embargo lapses the next call re-attempts and recovers
    # (forced expiry: deterministic, no sleep)
    cache.quarantine.get(cp.record.key).until = 0.0
    cp3 = stripe_jit("O[i, j] += A[i, c] * B[c, j]", CPU_TEST, "pallas",
                     cache=cache, **_mm_kwargs())
    assert not cp3.record.quarantined, "post-embargo retry must recompile"
    assert cache.stats.quarantine_clears == 1
    np.testing.assert_allclose(np.asarray(cp3(arrays)["O"]), want,
                               rtol=1e-4, atol=1e-5)


def test_unsupported_pallas_is_not_quarantined(tmp_path):
    # a deterministic legality fallback is not a crash: no quarantine entry
    cache = CompilationCache(disk_dir=tmp_path)
    prog = single_op_program(
        "O[x] += I[x + i - 1] * F[i]",
        {"I": ((12,), "float32"), "F": ((3,), "float32"), "O": ((12,), "float32")},
        out="O")
    cp = stripe_jit(prog, CPU_TEST, "pallas", cache=cache)
    _ = cp  # compiled (hybrid may fall back per-block); never quarantined
    assert cache.stats.quarantined == 0
    assert not cp.record.quarantined
