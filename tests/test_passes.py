"""Optimization-pass tests: every rewrite must preserve the exact semantics
of the reference interpreter, and the paper's Fig. 4/5 artifacts must be
reproduced."""
import copy

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import TileProgram, execute_reference, single_op_program, validate_program
from repro.core.cost import evaluate_tiling, lines_for_view
from repro.core.hwconfig import CPU_TEST, PAPER_FIG4, TPU_V5E
from repro.core.passes import PassManager, compile_program, get_pass
from repro.core.passes.autotile import choose_tiling
from repro.core.passes.boundary import split_boundary
from repro.core.tiling import split_block


def _conv_prog(h=12, w=16, cin=8, cout=16, dtype="int8", out_dtype="int32"):
    return single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {
            "I": ((h, w, cin), dtype),
            "F": ((3, 3, cin, cout), dtype),
            "O": ((h, w, cout), out_dtype),
        },
        out="O",
    )


def _matmul_prog(m, k, n):
    return single_op_program(
        "O[i, j] += A[i, c] * B[c, j]",
        {"A": ((m, k), "float32"), "B": ((k, n), "float32"), "O": ((m, n), "float32")},
        out="O",
    )


def _rand_inputs(prog, seed=0):
    rng = np.random.RandomState(seed)
    out = {}
    for name in prog.inputs:
        d = prog.buffers[name]
        if "int" in d.dtype:
            out[name] = rng.randint(-3, 4, size=d.shape).astype(np.dtype(d.dtype))
        else:
            out[name] = rng.randn(*d.shape).astype(np.dtype(d.dtype))
    return out


def _assert_same_outputs(prog_a, prog_b, inputs, **tol):
    ra = execute_reference(prog_a, inputs)
    rb = execute_reference(prog_b, inputs)
    for k in prog_a.outputs:
        np.testing.assert_allclose(ra[k], rb[k], **tol)


# --------------------------------------------------------------- split_block
def test_split_block_even_tiles_semantics():
    prog = _matmul_prog(6, 4, 8)
    tiled = copy.deepcopy(prog)
    blk = tiled.entry.stmts[0]
    tiled.entry.stmts[0] = split_block(blk, {"i": 3, "j": 4, "c": 2})
    assert validate_program(tiled) == []
    _assert_same_outputs(prog, tiled, _rand_inputs(prog), rtol=1e-5)


def test_split_block_uneven_overflow_constraint():
    prog = _matmul_prog(7, 5, 9)
    tiled = copy.deepcopy(prog)
    blk = tiled.entry.stmts[0]
    outer = split_block(blk, {"i": 3, "j": 4, "c": 2})
    tiled.entry.stmts[0] = outer
    inner = outer.stmts[0]
    # overflow constraints added, parent indices passed explicitly
    assert len(inner.constraints) == 3
    assert set(inner.passed) >= {"i", "j", "c"}
    assert validate_program(tiled) == []
    _assert_same_outputs(prog, tiled, _rand_inputs(prog, 1), rtol=1e-5)


def test_split_block_conv_halo_shapes():
    """Fig. 5b: 3x4x16 output tile => 5x6x8 haloed input view at offset
    [3x-1, 4y-1, 0]."""
    prog = _conv_prog()
    blk = copy.deepcopy(prog.entry.stmts[0])
    outer = split_block(blk, {"x": 3, "y": 4})
    i_ref = outer.ref("I")
    assert i_ref.shape == (5, 6, 8)
    assert str(i_ref.offsets[0]) == "3*x - 1"
    assert str(i_ref.offsets[1]) == "4*y - 1"
    o_ref = [r for r in outer.refs if r.agg][0]
    assert o_ref.shape == (3, 4, 16)
    # F is untouched by the tiling: full view at offset 0
    f_ref = outer.ref("F")
    assert f_ref.shape == (3, 3, 8, 16)


def test_split_block_conv_semantics_small():
    prog = _conv_prog(h=6, w=4, cin=2, cout=3)
    tiled = copy.deepcopy(prog)
    tiled.entry.stmts[0] = split_block(tiled.entry.stmts[0], {"x": 3, "y": 2, "k": 3})
    assert validate_program(tiled, limit=500000) == []
    _assert_same_outputs(prog, tiled, _rand_inputs(prog, 2))


@settings(max_examples=20, deadline=None)
@given(
    st.integers(2, 7), st.integers(2, 6), st.integers(2, 6),
    st.integers(1, 7), st.integers(1, 6), st.integers(1, 6),
)
def test_property_tiling_preserves_matmul(m, k, n, tm, tk, tn):
    prog = _matmul_prog(m, k, n)
    tiled = copy.deepcopy(prog)
    tiled.entry.stmts[0] = split_block(
        tiled.entry.stmts[0], {"i": min(tm, m), "c": min(tk, k), "j": min(tn, n)}
    )
    _assert_same_outputs(prog, tiled, _rand_inputs(prog, m * 100 + k * 10 + n), rtol=1e-5)


# --------------------------------------------------------------- Fig 4 cost
def test_fig4_cost_model_values():
    """The Fig. 5b tiling: input tile 5x6x8 = 30 lines (8-elem lines, c
    contiguous), output tile 3x4x16 = 24 lines, 13824 MACs per tile."""
    prog = _conv_prog()
    blk = prog.entry.stmts[0]
    cost = evaluate_tiling(
        blk, {"x": 3, "y": 4}, PAPER_FIG4,
        dict(PAPER_FIG4.passes[0][1]),
    )
    assert cost.feasible
    # 16 tiles x (30 + 24) lines
    assert cost.lines == 16 * 54
    # total MACs: interior-only (halo-constrained points removed)
    # = sum over (x,y) of valid (i,j) window x 8 x 16
    # exact count equals the polyhedron count
    assert cost.macs == blk.poly.count()
    # memory: 240 + 192 = 432 <= 512 cap
    assert cost.mem_elems == 240 + 192


def test_fig4_autotile_selects_feasible_minimum():
    prog = _conv_prog()
    blk = prog.entry.stmts[0]
    tiles, cost = choose_tiling(blk, PAPER_FIG4, dict(PAPER_FIG4.passes[0][1]))
    assert cost.feasible
    assert cost.mem_elems <= 512
    # the chosen tiling should not cost more than the paper's example tiling
    ref = evaluate_tiling(blk, {"x": 3, "y": 4}, PAPER_FIG4, dict(PAPER_FIG4.passes[0][1]))
    assert cost.cost <= ref.cost + 1e-12


def test_choose_tiling_coordinate_descent_fallback():
    """When the candidate cross-product exceeds ``max_combos`` the search
    falls back to greedy per-dimension refinement — the result must be
    feasible under the memory cap and deterministic across calls, and
    must match the fallback invoked directly."""
    from repro.core.passes.autotile import _candidates, _coordinate_descent

    prog = _matmul_prog(64, 32, 48)
    blk = prog.entry.stmts[0]
    params = {"cost": "cache_lines", "search": "exhaustive",
              "mem_cap_elems": 512, "max_combos": 50}
    n_combos = 64 * 32 * 48  # forces the fallback (> max_combos)
    assert n_combos > params["max_combos"]
    tiles, cost = choose_tiling(blk, PAPER_FIG4, params)
    assert cost.feasible
    assert cost.mem_elems <= 512
    tiles2, cost2 = choose_tiling(blk, PAPER_FIG4, params)
    assert tiles == tiles2 and cost.cost == cost2.cost
    free = {i.name: i.range for i in blk.idxs if not i.is_passthrough()}
    cands = {v: _candidates(free[v], "exhaustive") for v in free}
    t3, c3 = _coordinate_descent(blk, PAPER_FIG4, params, free, cands)
    assert t3 == tiles and c3.cost == cost.cost


def test_lines_for_view_alignment():
    from repro.core.ir import RefDir, Refinement
    from repro.core.affine import aff

    r = Refinement(dir=RefDir.IN, from_buf="X", into="X",
                   offsets=(aff(0), aff(0)), shape=(1, 1),
                   dtype="int8", strides=(16, 1))
    assert lines_for_view((4, 16), r, 8, aligned=True) == 4 * 2
    assert lines_for_view((4, 5), r, 8, aligned=False) == 4 * 2  # straddle
    assert lines_for_view((4, 5), r, 8, aligned=True) == 4 * 1


# ------------------------------------------------------------ full pipeline
def test_full_pipeline_paper_config_preserves_semantics():
    prog = _conv_prog(h=8, w=6, cin=2, cout=4)
    src = copy.deepcopy(prog)
    out = compile_program(prog, PAPER_FIG4)
    assert out.source is not None
    _assert_same_outputs(src, out, _rand_inputs(src, 3))


def test_full_pipeline_cpu_config_matmul():
    prog = _matmul_prog(16, 12, 8)
    src = copy.deepcopy(prog)
    out = compile_program(prog, CPU_TEST)
    _assert_same_outputs(src, out, _rand_inputs(src, 4), rtol=1e-5)
    assert validate_program(out, limit=500000) == []


# ------------------------------------------------------------------ boundary
def test_boundary_split_removes_interior_constraints():
    prog = _matmul_prog(7, 4, 4)
    blk = prog.entry.stmts[0]
    outer = split_block(blk, {"i": 3})
    pieces = split_boundary(outer)
    assert len(pieces) == 2
    interior, boundary = pieces

    def count(b):
        n = len(b.constraints)
        for s in b.stmts:
            if hasattr(s, "constraints"):
                n += count(s)
        return n

    assert count(interior) == 0  # constraint-free interior
    assert count(boundary) >= 1
    # semantics preserved
    tiled = copy.deepcopy(prog)
    tiled.entry.stmts = pieces
    _assert_same_outputs(prog, tiled, _rand_inputs(prog, 5), rtol=1e-5)


# ---------------------------------------------------------------------- fuse
def _mlp_prog(m=6, k=5, n=4):
    tp = TileProgram("mlp")
    tp.input("A", (m, k))
    tp.input("B", (k, n))
    tp.temp("T", (m, n))
    tp.output("O", (m, n))
    tp.op("T[i, j] += A[i, c] * B[c, j]")
    tp.op("O[i, j] = relu(T[i, j])")
    return tp.build()


def test_fuse_matmul_relu():
    prog = _mlp_prog()
    src = copy.deepcopy(prog)
    fused = get_pass("fuse")(prog, TPU_V5E, {})
    blocks = [s for s in fused.entry.stmts if hasattr(s, "tags")]
    assert len(blocks) == 1 and "fused" in blocks[0].tags
    assert validate_program(fused) == []
    _assert_same_outputs(src, fused, _rand_inputs(src, 6), rtol=1e-5)


def test_fuse_then_autotile_preserves_semantics():
    prog = _mlp_prog(8, 6, 8)
    src = copy.deepcopy(prog)
    prog = get_pass("fuse")(prog, CPU_TEST, {})
    prog = get_pass("autotile")(prog, CPU_TEST, {"cost": "cache_lines", "search": "pow2", "mem_cap_elems": 64})
    assert validate_program(prog, limit=500000) == []
    _assert_same_outputs(src, prog, _rand_inputs(src, 7), rtol=1e-5)


def test_fuse_skipped_when_temp_multiply_read():
    tp = TileProgram("p")
    tp.input("A", (4, 4))
    tp.input("B", (4, 4))
    tp.temp("T", (4, 4))
    tp.output("O", (4, 4))
    tp.output("P", (4, 4))
    tp.op("T[i, j] += A[i, c] * B[c, j]")
    tp.op("O[i, j] = relu(T[i, j])")
    tp.op("P[i, j] = tanh(T[i, j])")
    prog = tp.build()
    fused = get_pass("fuse")(prog, TPU_V5E, {})
    assert len([s for s in fused.entry.stmts if hasattr(s, "tags")]) == 3


# ------------------------------------------------------------------- stencil
def test_stencil_pass_tags_mxu():
    prog = _matmul_prog(256, 256, 256)
    prog = get_pass("autotile")(prog, TPU_V5E, {"cost": "roofline", "search": "pow2", "mem_cap_frac": 0.45})
    prog = get_pass("stencil")(prog, TPU_V5E, {"stencil": "mxu", "min_dim": 16})
    tagged = [b for s in prog.entry.stmts if hasattr(s, "walk") for b in s.walk() if "mxu" in b.tags]
    assert tagged, "expected an mxu-tagged innermost block"


# ----------------------------------------------------------------- transpose
def test_transpose_pass_inserts_copy():
    prog = single_op_program(
        "O[i, j] += A[c, i] * B[c, j]",
        {"A": ((4, 6), "float32"), "B": ((4, 5), "float32"), "O": ((6, 5), "float32")},
        out="O",
    )
    src = copy.deepcopy(prog)
    out = get_pass("transpose")(prog, TPU_V5E, {})
    names = [s.name for s in out.entry.stmts if hasattr(s, "name")]
    assert any("transpose" in n for n in names)
    _assert_same_outputs(src, out, _rand_inputs(src, 8), rtol=1e-5)


# ----------------------------------------------------------------- partition
def test_partition_pass_banks():
    prog = _matmul_prog(8, 4, 4)
    src = copy.deepcopy(prog)
    out = get_pass("partition")(prog, CPU_TEST, {"n_units": 4})
    blk = out.entry.stmts[0]
    assert any(t.startswith("partition:") for t in blk.tags)
    banked = [r for r in blk.refs if r.location and r.location.bank is not None]
    assert banked
    _assert_same_outputs(src, out, _rand_inputs(src, 9), rtol=1e-5)


# ------------------------------------------------------------------ schedule
def test_schedule_dag_and_levels():
    from repro.core.passes.schedule import dependency_dag, wavefronts

    tp = TileProgram("p")
    tp.input("A", (4, 4))
    tp.temp("T", (4, 4))
    tp.temp("U", (4, 4))
    tp.output("O", (4, 4))
    tp.op("T[i, j] = relu(A[i, j])")
    tp.op("U[i, j] = tanh(A[i, j])")   # independent of T
    tp.op("O[i, j] += T[i, c] * U[c, j]")
    prog = tp.build()
    blocks = [s for s in prog.entry.stmts if hasattr(s, "refs")]
    deps = dependency_dag(blocks)
    assert deps[1] == set()            # U does not depend on T
    assert deps[2] == {0, 1}
    assert wavefronts(deps) == [0, 0, 1]


# ------------------------------------------------------- localize + schedule
def test_localize_assigns_locations_and_gcs_temp():
    prog = _mlp_prog()
    prog = get_pass("fuse")(prog, TPU_V5E, {})
    prog = get_pass("autotile")(prog, TPU_V5E, {"cost": "roofline", "search": "pow2", "mem_cap_frac": 0.45})
    prog = get_pass("localize")(prog, TPU_V5E, {"inner": "VMEM"})
    assert "T" not in prog.buffers  # scalarized away
    locs = set()
    for s in prog.entry.stmts:
        if hasattr(s, "walk"):
            for b in s.walk():
                for r in b.refs:
                    if r.location:
                        locs.add(r.location.unit)
    assert "HBM" in locs and ("VMEM" in locs or "VREG" in locs)


def test_tpu_pipeline_end_to_end_semantics():
    prog = _mlp_prog(8, 8, 8)
    src = copy.deepcopy(prog)
    out = compile_program(prog, TPU_V5E)
    assert validate_program(out, limit=500000) == []
    _assert_same_outputs(src, out, _rand_inputs(src, 10), rtol=1e-5)
