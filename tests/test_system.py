"""End-to-end system behaviour tests: frontend -> passes -> backends ->
models -> training, exercising the whole stack in one path."""
import copy

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import TileProgram, execute_reference, validate_program
from repro.core.hwconfig import PAPER_FIG4, TPU_V5E
from repro.core.lower_jnp import lower_program_jnp
from repro.core.passes import compile_program


def test_end_to_end_compile_and_execute():
    """The quickstart path: Tile op -> TPU pipeline -> both executors."""
    tp = TileProgram("mlp")
    tp.input("X", (64, 96))
    tp.input("W", (96, 48))
    tp.input("B", (48,))
    tp.temp("T", (64, 48))
    tp.output("O", (64, 48))
    tp.op("T[i, j] += X[i, c] * W[c, j]")
    tp.op("O[i, j] = relu(T[i, j] + B[j])")
    prog = tp.build()
    assert validate_program(prog) == []
    src = copy.deepcopy(prog)
    opt = compile_program(prog, TPU_V5E)

    rng = np.random.RandomState(0)
    arrays = {"X": rng.randn(64, 96).astype(np.float32),
              "W": rng.randn(96, 48).astype(np.float32),
              "B": rng.randn(48).astype(np.float32)}
    want = np.maximum(arrays["X"] @ arrays["W"] + arrays["B"], 0)
    # reference interpreter on the OPTIMIZED program (proves the rewrites)
    got_interp = execute_reference(opt, arrays)["O"]
    np.testing.assert_allclose(got_interp, want, rtol=1e-4, atol=1e-5)
    # jnp backend from the preserved semantic source
    got_jnp = lower_program_jnp(opt.source)({k: jnp.asarray(v) for k, v in arrays.items()})["O"]
    np.testing.assert_allclose(np.asarray(got_jnp), want, rtol=1e-4, atol=1e-5)


def test_autotiler_reproduces_paper_fig5b_tiling():
    """On the paper's own Fig. 4 machine, the autotiler independently
    derives the Fig. 5b tiling cost (3x4 spatial tiles, full channels,
    54 cache lines per tile pair, 432-element footprint <= 512 cap)."""
    from repro.core.cost import evaluate_tiling
    from repro.core.frontend import single_op_program
    from repro.core.passes.autotile import choose_tiling

    prog = single_op_program(
        "O[x, y, k] += I[x + i - 1, y + j - 1, c] * F[i, j, c, k]",
        {"I": ((12, 16, 8), "int8"), "F": ((3, 3, 8, 16), "int8"),
         "O": ((12, 16, 16), "int32")},
        out="O",
    )
    blk = prog.entry.stmts[0]
    params = dict(PAPER_FIG4.passes[0][1])
    tiles, best = choose_tiling(blk, PAPER_FIG4, params)
    ref = evaluate_tiling(blk, {"x": 3, "y": 4}, PAPER_FIG4, params)
    assert best.feasible and best.mem_elems <= 512
    assert abs(best.cost - ref.cost) < 1e-12  # same optimum as the paper's example
    assert tiles["x"] == 3 and tiles["y"] == 4


def test_all_archs_build_and_param_counts_sane():
    expected_scale = {
        "xlstm-125m": (0.08e9, 0.4e9),
        "nemotron-4-15b": (12e9, 20e9),
        "chatglm3-6b": (5e9, 9e9),
        "llama3-8b": (6e9, 10e9),
        "qwen3-4b": (3e9, 6e9),
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        "dbrx-132b": (110e9, 150e9),
        "internvl2-26b": (18e9, 30e9),
        "seamless-m4t-large-v2": (1.5e9, 4e9),
        "zamba2-2.7b": (2e9, 4e9),
    }
    for name, (lo, hi) in expected_scale.items():
        n = configs.get(name).param_count()
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_roofline_analysis_runs_on_recorded_results():
    import json
    import os

    from repro.launch.roofline import analyze, markdown_table

    path = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                        "dryrun_baseline.json")
    if not os.path.exists(path):
        import pytest

        pytest.skip("no dry-run results recorded")
    rows = analyze(json.load(open(path)))
    assert len(rows) >= 60  # 32 cells x 2 meshes
    assert all(r["roofline_fraction"] <= 1.0 + 1e-9 for r in rows)
    table = markdown_table(rows)
    assert "dominant" in table


def test_hlo_collective_parser():
    from repro.launch.hlo_stats import collective_stats

    hlo = """
ENTRY %main {
  %p0 = f32[8,16]{1,0} parameter(0)
  %ag = f32[64,16]{1,0} all-gather(%p0), replica_groups={}
  %w = f32[64,16]{1,0} while(%ag), condition=%cond.1, body=%body.2
}
%body.2 (x: f32[64,16]) -> f32[64,16] {
  %x = f32[64,16]{1,0} parameter(0)
  %ar = f32[64,16]{1,0} all-reduce(%x), to_apply=%add
}
"""
    stats = collective_stats(hlo, body_multiplier=10)
    assert stats["all-gather"]["count"] == 1
    assert stats["all-gather"]["operand_bytes"] == 8 * 16 * 4
    assert stats["all-reduce"]["count"] == 10  # body multiplied
    assert stats["all-reduce"]["operand_bytes"] == 64 * 16 * 4 * 10
